//! Indexed UP-priority queue and shared per-lane queue storage.
//!
//! The historical `UaSched` re-sorted a whole lane queue with freshly
//! computed UP keys on every pop — O(n log n) per dispatch, which melts
//! at production queue depths (see `benches/hotpath.rs`). This module
//! replaces the resort with an indexed structure, [`UpQueue`], that is
//! *order-equivalent to the keyed full-sort oracle* yet pops the top
//! `k` tasks in roughly O(k log k + R) at any depth, and a shared
//! [`PolicyQueues`] helper that owns per-lane storage, the monotone
//! insertion sequence, and overload shedding for every policy.
//!
//! # Why the index can be exact
//!
//! The UP priority (Eq. 3, `up::up_priority`) of a task with static
//! numerator `n = 1 - alpha * u_hat` and static slack offset
//! `s = d - eta * u` is, at scheduling time `t`:
//!
//! - **normal** regime (`s - t >= min_slack`): `p = n / (s - t)` —
//!   relative order between two tasks *can* change over time (pairwise
//!   crossings), so no static order exists; but a bucket of tasks whose
//!   `n` lies in `[lo_r, hi_r]` admits the upper bound
//!   `p <= hi_r / (s_min - t)`, which makes exact best-first selection
//!   possible without sorting;
//! - **overdue** regime (`s - t < min_slack`):
//!   `p = (n - s + t + min_slack) / min_slack` — order by `n - s`
//!   descending is *time-invariant*, so one sorted list stays correct
//!   forever. Tasks only ever flow normal -> overdue (`t` is monotone).
//!
//! So the structure is: one statically-sorted overdue list, `R`
//! buckets over quantised `n` each sorted by `s`, and a tiny
//! "exact" bin for entries with non-finite keys or sitting within a
//! floating-point guard band of the regime boundary. A pop promotes
//! boundary-crossing tasks lazily (each task rebuckets at most once,
//! plus once more per ξ-era re-push), then runs best-first selection:
//! candidates are expanded from each source while the source's inflated
//! upper bound could still beat the current best *exact* key, and ties
//! break exactly like the oracle's stable sort — `(p desc, arrival
//! asc, seq asc)`, where `seq` is the monotone insertion sequence (a
//! stable sort of an insertion-ordered queue breaks ties by insertion
//! order). Bounds are inflated by a relative margin that provably
//! dominates every floating-point discrepancy between the cached
//! static keys and the oracle's freshly-computed ones, so inflation
//! can only cause extra candidate expansion, never a misordering.
//! Exact keys are always computed by calling [`up_priority`] on the
//! stored task — bit-identical to the oracle's keys by construction.
//!
//! Both the overdue list and the buckets are stored *reversed* — the
//! dispatch-first end is the **back** of the `Vec` (for buckets, in the
//! ubiquitous non-negative-numerator case `alpha <= 1`). Pops remove
//! from the hot end, so `Vec::remove(last)` is O(1) and per-pop cost
//! stays flat as depth grows — the property `benches/hotpath.rs` sweeps
//! across 10^3..10^6 queued tasks. Storage order is invisible to
//! callers: selection order is fixed by exact keys, not storage.
//!
//! The equivalence is pinned by property tests below (random traces ×
//! promotions × re-pushes against the keyed full-sort oracle) and by
//! the cross-backend dispatch-equality suites in `tests/`.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::config::{SchedParams, ShedPolicy};

use super::lane::LaneId;
use super::task::Task;
use super::up::up_priority;

/// Quantisation ranks for the normal-regime numerator buckets.
const RANKS: usize = 64;

/// Relative width of the promotion guard band: bucket entries within
/// `GUARD_REL * (|s| + |now| + 1)` of the regime boundary are moved to
/// the exact bin, so every entry *remaining* in a bucket is provably in
/// the normal regime under the oracle's own (differently-rounded)
/// slack expression.
const GUARD_REL: f64 = 1e-9;

/// Bound inflation: dominates both the bucket-index rounding slop and
/// the `s - now` vs `(d - now) - eta*u` rounding difference (which is
/// at most ~1e-6 of the guard band), so an inflated bound is a true
/// upper bound on every member's exact key.
fn inflate(x: f64) -> f64 {
    x + x.abs() * 1e-5 + 1e-300
}

/// Sources inside an [`UpQueue`], encoded in [`EntryRef::src`].
const SRC_OVERDUE: u32 = 0;
const SRC_EXACT: u32 = u32::MAX;

/// One queued task's index record: the static key components and the
/// slot of the task itself.
#[derive(Clone, Copy, Debug)]
struct Entry {
    /// Static slack offset `d - eta * u` (the dynamic slack is `s - t`).
    s: f64,
    /// Static UP numerator `1 - alpha * u_hat`.
    n: f64,
    /// Arrival time (first oracle tie-break).
    arrival: f64,
    /// Monotone insertion sequence (second oracle tie-break — the
    /// stable-sort stand-in).
    seq: u64,
    /// Index into the task slab.
    slot: u32,
}

/// A handle to one entry, valid until the queue is next mutated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EntryRef {
    src: u32,
    idx: u32,
}

/// Heap candidate with its exact oracle key; the heap's max is the
/// next task in exact dispatch order.
struct Cand {
    key: f64,
    arrival: f64,
    seq: u64,
    r: EntryRef,
}

impl Cand {
    fn order(&self, other: &Cand) -> Ordering {
        self.key
            .total_cmp(&other.key)
            .then_with(|| other.arrival.total_cmp(&self.arrival))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialEq for Cand {
    fn eq(&self, other: &Cand) -> bool {
        self.order(other) == Ordering::Equal
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Cand) -> Option<Ordering> {
        Some(self.order(other))
    }
}
impl Ord for Cand {
    fn cmp(&self, other: &Cand) -> Ordering {
        self.order(other)
    }
}

/// The indexed UP-priority queue for one accelerator-kind lane.
pub struct UpQueue {
    params: SchedParams,
    eta: f64,
    /// Lower edge and per-rank width of the numerator quantisation
    /// (width 0 = degenerate: everything in bucket 0).
    n_lo: f64,
    n_hi: f64,
    n_width: f64,
    /// Task slab: entries address tasks by slot so rebucketing moves
    /// 40-byte index records, not whole tasks.
    slots: Vec<Option<Task>>,
    free: Vec<u32>,
    /// Overdue tasks in a static order that equals the dynamic one at
    /// every time, stored reversed — `(n - s asc, arrival desc, seq
    /// desc)` — so the dispatch-first entry is the *back* element and
    /// hot removals are O(1).
    overdue: Vec<Entry>,
    /// Normal-regime tasks bucketed by quantised `n`, each bucket
    /// stored reversed — `(s desc, arrival desc, seq desc)` — so for
    /// non-negative-numerator ranks the best entry is the back element.
    buckets: Vec<Vec<Entry>>,
    /// Non-finite keys and guard-band boundary entries: exact-evaluated
    /// on every pop. Stays tiny — boundary entries cross into the
    /// overdue list as soon as the clock passes them.
    exact: Vec<Entry>,
    len: usize,
}

impl UpQueue {
    /// Build an empty queue for a lane scheduled with `params` and the
    /// serving model's tokens->seconds coefficient `eta`. Requires
    /// `params.min_slack >= 0` (the default; Eq. 3 is ill-posed below
    /// zero).
    pub fn new(params: SchedParams, eta: f64) -> UpQueue {
        debug_assert!(params.min_slack >= 0.0, "UpQueue requires min_slack >= 0");
        // u_hat ranges over [0, 1], so n = 1 - alpha * u_hat spans the
        // interval between 1 and 1 - alpha (either way round).
        let a = 1.0;
        let b = 1.0 - params.alpha;
        let (n_lo, n_hi) = if b < a { (b, a) } else { (a, b) };
        let w = (n_hi - n_lo) / RANKS as f64;
        let n_width = if w.is_finite() && w > 0.0 { w } else { 0.0 };
        UpQueue {
            params,
            eta,
            n_lo,
            n_hi,
            n_width,
            slots: Vec::new(),
            free: Vec::new(),
            overdue: Vec::new(),
            buckets: (0..RANKS).map(|_| Vec::new()).collect(),
            exact: Vec::new(),
            len: 0,
        }
    }

    /// Queued task count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The exact oracle priority of any task under this queue's
    /// parameters (also used for shed decisions on not-yet-inserted
    /// arrivals).
    pub fn priority_of(&self, task: &Task, now: f64) -> f64 {
        up_priority(task, &self.params, self.eta, now)
    }

    fn store(&mut self, task: Task) -> u32 {
        match self.free.pop() {
            Some(i) => {
                self.slots[i as usize] = Some(task);
                i
            }
            None => {
                self.slots.push(Some(task));
                (self.slots.len() - 1) as u32
            }
        }
    }

    fn release(&mut self, slot: u32) -> Task {
        self.free.push(slot);
        self.slots[slot as usize].take().expect("released slot holds a task")
    }

    fn task_of(&self, e: &Entry) -> &Task {
        self.slots[e.slot as usize].as_ref().expect("live entry has a task")
    }

    /// The task behind a selector-produced handle.
    pub fn task(&self, r: EntryRef) -> &Task {
        self.task_of(self.entry(r))
    }

    fn entry(&self, r: EntryRef) -> &Entry {
        match r.src {
            SRC_OVERDUE => &self.overdue[r.idx as usize],
            SRC_EXACT => &self.exact[r.idx as usize],
            b => &self.buckets[(b - 1) as usize][r.idx as usize],
        }
    }

    fn bucket_of(&self, n: f64) -> usize {
        if self.n_width <= 0.0 {
            return 0;
        }
        // `as usize` saturates tiny negative slop to 0
        (((n - self.n_lo) / self.n_width) as usize).min(RANKS - 1)
    }

    /// Upper edge of bucket `b`'s numerator range, inflated past the
    /// index-computation rounding slop so it bounds every member's
    /// true `n`.
    fn bucket_hi(&self, b: usize) -> f64 {
        if self.n_width <= 0.0 {
            return inflate(self.n_hi.max(self.n_lo));
        }
        inflate(self.n_lo + (b + 1) as f64 * self.n_width)
    }

    /// Exact oracle key of an entry at time `now` — computed from the
    /// stored task by the same expression the full-sort oracle uses.
    fn key_of(&self, e: &Entry, now: f64) -> f64 {
        up_priority(self.task_of(e), &self.params, self.eta, now)
    }

    /// Admit one task with its monotone insertion sequence number.
    /// Placement needs no clock: an already-overdue entry lands at its
    /// bucket's hot end and the next pop's promotion sweep moves it.
    pub fn insert(&mut self, task: Task, seq: u64) {
        let u_hat = (task.uncertainty / self.params.u_scale).clamp(0.0, 1.0);
        let n = 1.0 - self.params.alpha * u_hat;
        let s = task.priority_point - self.eta * task.uncertainty;
        let arrival = task.arrival;
        let slot = self.store(task);
        let e = Entry { s, n, arrival, seq, slot };
        if n.is_nan() || s.is_nan() {
            self.exact.push(e);
        } else {
            let b = self.bucket_of(n);
            let q = &mut self.buckets[b];
            // reversed storage: e goes after every entry with a larger
            // (s, arrival, seq) — the back of the bucket is the
            // smallest-s (dispatch-first) end
            let pos = q.partition_point(|x| {
                x.s.total_cmp(&e.s)
                    .then(x.arrival.total_cmp(&e.arrival))
                    .then(x.seq.cmp(&e.seq))
                    .is_gt()
            });
            q.insert(pos, e);
        }
        self.len += 1;
    }

    fn insert_overdue(&mut self, e: Entry) {
        let k = e.n - e.s;
        // reversed storage: x stays before e while x's n-s is *smaller*
        // (ties: later arrival, later seq first) — the back of the list
        // is the dispatch-first end
        let pos = self.overdue.partition_point(|x| {
            (x.n - x.s)
                .total_cmp(&k)
                .then(e.arrival.total_cmp(&x.arrival))
                .then(e.seq.cmp(&x.seq))
                .is_lt()
        });
        self.overdue.insert(pos, e);
    }

    /// Move every entry whose regime flipped into the overdue list —
    /// the "rebucket on ξ-promotion" step. Entries inside the guard
    /// band go to the exact bin until the oracle's own slack test
    /// settles them (at most a few clock-instants later).
    pub fn promote(&mut self, now: f64) {
        let ms = self.params.min_slack;
        for b in 0..self.buckets.len() {
            // smallest-s entries sit at the back (reversed storage), so
            // the boundary-crossing sweep peels a suffix — O(drained),
            // no memmove of the survivors
            let len = self.buckets[b].len();
            let mut p = len;
            while p > 0 {
                let e = &self.buckets[b][p - 1];
                let g = GUARD_REL * (e.s.abs() + now.abs() + 1.0);
                if e.s - now < ms + g {
                    p -= 1;
                } else {
                    break;
                }
            }
            if p == len {
                continue;
            }
            let drained: Vec<Entry> = self.buckets[b].drain(p..).collect();
            for e in drained {
                // the oracle's branch condition, on the oracle's own
                // floating-point expression
                let raw = self.task_of(&e).slack_at(self.eta, now);
                if raw >= ms {
                    self.exact.push(e); // boundary: exact-evaluate until it crosses
                } else {
                    self.insert_overdue(e);
                }
            }
        }
        let mut i = 0;
        while i < self.exact.len() {
            let e = self.exact[i];
            if e.n.is_nan() || e.s.is_nan() {
                i += 1;
                continue;
            }
            let raw = self.task_of(&e).slack_at(self.eta, now);
            if raw >= ms {
                i += 1;
            } else {
                let e = self.exact.swap_remove(i);
                self.insert_overdue(e);
            }
        }
    }

    /// Remove the given entries (in selection order) and return their
    /// tasks, preserving that order.
    pub fn remove_selected(&mut self, picked: &[EntryRef]) -> Vec<Task> {
        let slots: Vec<u32> = picked.iter().map(|r| self.entry(*r).slot).collect();
        let mut by = picked.to_vec();
        by.sort_by(|a, b| (b.src, b.idx).cmp(&(a.src, a.idx)));
        for r in by {
            match r.src {
                SRC_OVERDUE => {
                    self.overdue.remove(r.idx as usize);
                }
                // descending-index removal keeps remaining picks valid —
                // and because storage is reversed (hot end = back),
                // selection-order picks are the *highest* indices, so
                // the common case is `remove(last)`: an O(1) pop, no
                // memmove. The exact bin is unordered, so swap_remove
                // is safe (the element it moves sits above every
                // remaining pick).
                SRC_EXACT => {
                    self.exact.swap_remove(r.idx as usize);
                }
                b => {
                    self.buckets[(b - 1) as usize].remove(r.idx as usize);
                }
            }
        }
        self.len -= picked.len();
        slots.into_iter().map(|s| self.release(s)).collect()
    }

    /// Pop the top `k` tasks in exact oracle order (promotes first).
    pub fn pop_top(&mut self, now: f64, k: usize) -> Vec<Task> {
        self.promote(now);
        let mut picked = Vec::with_capacity(k.min(self.len));
        {
            let mut sel = Selector::new(self, now);
            while picked.len() < k {
                match sel.next() {
                    Some(r) => picked.push(r),
                    None => break,
                }
            }
        }
        self.remove_selected(&picked)
    }

    /// Pop up to `k` tasks in *insertion* order — the quarantine-lane
    /// FIFO semantics, kept for direct stepped pops on non-accelerator
    /// lanes (the engine never issues these; see `UaSched::pop`).
    pub fn pop_fifo_order(&mut self, k: usize) -> Vec<Task> {
        let mut refs: Vec<(u64, EntryRef)> = self
            .entry_refs()
            .map(|(r, e)| (e.seq, r))
            .collect();
        refs.sort_unstable_by_key(|&(seq, _)| seq);
        refs.truncate(k);
        let picked: Vec<EntryRef> = refs.into_iter().map(|(_, r)| r).collect();
        self.remove_selected(&picked)
    }

    fn entry_refs(&self) -> impl Iterator<Item = (EntryRef, &Entry)> + '_ {
        let overdue = self
            .overdue
            .iter()
            .enumerate()
            .map(|(i, e)| (EntryRef { src: SRC_OVERDUE, idx: i as u32 }, e));
        let buckets = self.buckets.iter().enumerate().flat_map(|(b, q)| {
            q.iter()
                .enumerate()
                .map(move |(i, e)| (EntryRef { src: 1 + b as u32, idx: i as u32 }, e))
        });
        let exact = self
            .exact
            .iter()
            .enumerate()
            .map(|(i, e)| (EntryRef { src: SRC_EXACT, idx: i as u32 }, e));
        overdue.chain(buckets).chain(exact)
    }

    /// Iterate the queued tasks (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = &Task> + '_ {
        self.entry_refs().map(|(_, e)| self.task_of(e))
    }

    /// Earliest arrival among queued tasks (`+inf` when empty) — the
    /// ξ-window anchor.
    pub fn min_arrival(&self) -> f64 {
        self.entry_refs().map(|(_, e)| e.arrival).fold(f64::INFINITY, f64::min)
    }

    /// The lowest-priority queued task under the exact oracle order at
    /// `now` — the `--shed priority` victim (ties: latest arrival, then
    /// latest insertion). O(n) scan; runs only when a lane is at cap.
    pub fn worst_by_priority(&self, now: f64) -> Option<(EntryRef, f64, f64)> {
        let mut worst: Option<(EntryRef, f64, f64, u64)> = None;
        for (r, e) in self.entry_refs() {
            let key = self.key_of(e, now);
            let worse = match &worst {
                None => true,
                Some((_, wk, wa, ws)) => matches!(
                    key.total_cmp(wk)
                        .then(wa.total_cmp(&e.arrival))
                        .then(ws.cmp(&e.seq)),
                    Ordering::Less
                ),
            };
            if worse {
                worst = Some((r, key, e.arrival, e.seq));
            }
        }
        worst.map(|(r, k, a, _)| (r, k, a))
    }

    /// The highest-predicted-length queued task — the `--shed length`
    /// victim (ties: latest insertion).
    pub fn worst_by_length(&self) -> Option<(EntryRef, f64)> {
        let mut worst: Option<(EntryRef, f64, u64)> = None;
        for (r, e) in self.entry_refs() {
            let u = self.task_of(e).uncertainty;
            let worse = match &worst {
                None => true,
                Some((_, wu, ws)) => matches!(
                    u.total_cmp(wu).then(e.seq.cmp(ws)),
                    Ordering::Greater
                ),
            };
            if worse {
                worst = Some((r, u, e.seq));
            }
        }
        worst.map(|(r, u, _)| (r, u))
    }

    /// Remove one entry by handle.
    pub fn remove_at(&mut self, r: EntryRef) -> Task {
        let slot = self.entry(r).slot;
        match r.src {
            SRC_OVERDUE => {
                self.overdue.remove(r.idx as usize);
            }
            SRC_EXACT => {
                self.exact.swap_remove(r.idx as usize);
            }
            b => {
                self.buckets[(b - 1) as usize].remove(r.idx as usize);
            }
        }
        self.len -= 1;
        self.release(slot)
    }

    /// Drain every queued task (overdue first, then buckets, then the
    /// exact bin) — lane retirement re-admits these elsewhere.
    pub fn drain_all(&mut self) -> Vec<Task> {
        let mut entries: Vec<Entry> = Vec::with_capacity(self.len);
        // .rev() undoes the reversed storage: callers see dispatch-first
        // order per source, independent of the internal layout
        entries.extend(self.overdue.drain(..).rev());
        for b in &mut self.buckets {
            entries.extend(b.drain(..).rev());
        }
        entries.extend(self.exact.drain(..));
        self.len = 0;
        entries.into_iter().map(|e| self.release(e.slot)).collect()
    }
}

/// Lazy exact-order enumerator over an [`UpQueue`] (call
/// [`UpQueue::promote`] first). Each `next` returns the handle of the
/// globally next task in oracle order without mutating the queue, so a
/// caller can walk, skip, and only then remove what it actually took.
pub struct Selector<'a> {
    q: &'a UpQueue,
    now: f64,
    heap: BinaryHeap<Cand>,
    over_cur: usize,
    taken: Vec<usize>,
}

impl<'a> Selector<'a> {
    /// Start a selection pass at time `now` (the same `now` promote ran
    /// with).
    pub fn new(q: &'a UpQueue, now: f64) -> Selector<'a> {
        let mut heap = BinaryHeap::new();
        // the exact bin is evaluated eagerly — it holds only non-finite
        // keys and boundary stragglers, so it stays tiny
        for (i, e) in q.exact.iter().enumerate() {
            heap.push(Cand {
                key: q.key_of(e, now),
                arrival: e.arrival,
                seq: e.seq,
                r: EntryRef { src: SRC_EXACT, idx: i as u32 },
            });
        }
        Selector { q, now, heap, over_cur: 0, taken: vec![0; q.buckets.len()] }
    }

    fn beats_top(&self, bound: f64) -> bool {
        match self.heap.peek() {
            None => true,
            // expand on ties too: an equal-key element may win the
            // arrival/seq tie-break
            Some(c) => bound.total_cmp(&c.key) != Ordering::Less,
        }
    }

    /// Physical index of the next unexpanded overdue entry — the list
    /// is stored reversed, so the cursor walks from the back.
    fn overdue_idx(&self) -> Option<usize> {
        let n = self.q.overdue.len();
        (self.over_cur < n).then(|| n - 1 - self.over_cur)
    }

    fn overdue_bound(&self) -> Option<f64> {
        self.overdue_idx()
            .map(|i| inflate(self.q.key_of(&self.q.overdue[i], self.now)))
    }

    fn expand_overdue(&mut self) {
        let i = self.overdue_idx().expect("expand past overdue end");
        let e = &self.q.overdue[i];
        self.heap.push(Cand {
            key: self.q.key_of(e, self.now),
            arrival: e.arrival,
            seq: e.seq,
            r: EntryRef { src: SRC_OVERDUE, idx: i as u32 },
        });
        self.over_cur += 1;
    }

    fn bucket_bound(&self, b: usize) -> Option<f64> {
        let q = &self.q.buckets[b];
        if self.taken[b] >= q.len() {
            return None;
        }
        let hi = self.q.bucket_hi(b);
        // hi >= 0: p = n/(s-t) is maximised by small s — and buckets
        // are stored s-descending, so expand from the back. hi < 0:
        // maximised by large s — expand from the front. Either way the
        // cursor element carries the extremal s of the unexpanded
        // remainder.
        let e = if hi >= 0.0 {
            &q[q.len() - 1 - self.taken[b]]
        } else {
            &q[self.taken[b]]
        };
        Some(inflate(hi / (e.s - self.now)))
    }

    fn expand_bucket(&mut self, b: usize) {
        let q = &self.q.buckets[b];
        let idx = if self.q.bucket_hi(b) >= 0.0 {
            q.len() - 1 - self.taken[b]
        } else {
            self.taken[b]
        };
        let e = &q[idx];
        self.heap.push(Cand {
            key: self.q.key_of(e, self.now),
            arrival: e.arrival,
            seq: e.seq,
            r: EntryRef { src: 1 + b as u32, idx: idx as u32 },
        });
        self.taken[b] += 1;
    }

    /// The next entry in exact oracle order, or `None` when exhausted.
    pub fn next(&mut self) -> Option<EntryRef> {
        loop {
            let mut grew = false;
            while let Some(b) = self.overdue_bound() {
                if self.beats_top(b) {
                    self.expand_overdue();
                    grew = true;
                } else {
                    break;
                }
            }
            for b in 0..self.taken.len() {
                while let Some(bound) = self.bucket_bound(b) {
                    if self.beats_top(bound) {
                        self.expand_bucket(b);
                        grew = true;
                    } else {
                        break;
                    }
                }
            }
            if !grew {
                break;
            }
        }
        self.heap.pop().map(|c| c.r)
    }
}

/// Per-lane queue storage flavours.
pub enum LaneQ {
    /// Insertion-order queue (FIFO baselines, CPU quarantine lanes).
    Fifo(VecDeque<Task>),
    /// Key-sorted queue (HPF/LUF/MUF): ascending key, ties by arrival,
    /// dispatch from the front.
    Keyed { key: Box<dyn Fn(&Task) -> f64 + Send>, queue: Vec<Task> },
    /// Indexed UP-priority queue (accelerator lanes of `UaSched`).
    Up(UpQueue),
}

impl LaneQ {
    /// An insertion-order lane queue.
    pub fn fifo() -> LaneQ {
        LaneQ::Fifo(VecDeque::new())
    }

    /// A key-sorted lane queue.
    pub fn keyed(key: Box<dyn Fn(&Task) -> f64 + Send>) -> LaneQ {
        LaneQ::Keyed { key, queue: Vec::new() }
    }

    /// An indexed UP lane queue.
    pub fn up(params: SchedParams, eta: f64) -> LaneQ {
        LaneQ::Up(UpQueue::new(params, eta))
    }

    /// Queued task count.
    pub fn len(&self) -> usize {
        match self {
            LaneQ::Fifo(q) => q.len(),
            LaneQ::Keyed { queue, .. } => queue.len(),
            LaneQ::Up(q) => q.len(),
        }
    }

    /// Is this lane queue empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Earliest queued arrival (`+inf` when empty).
    pub fn min_arrival(&self) -> f64 {
        match self {
            LaneQ::Fifo(q) => q.iter().map(|t| t.arrival).fold(f64::INFINITY, f64::min),
            LaneQ::Keyed { queue, .. } => {
                queue.iter().map(|t| t.arrival).fold(f64::INFINITY, f64::min)
            }
            LaneQ::Up(q) => q.min_arrival(),
        }
    }
}

/// Shared per-lane queue storage: owns the lane queues, the monotone
/// insertion sequence that stands in for stable-sort tie-breaking, and
/// overload admission control (`queue_cap` / [`ShedPolicy`]). Policies
/// keep only their ordering logic.
pub struct PolicyQueues {
    queues: Vec<LaneQ>,
    /// Lane id reported for sheds out of `queues[i]` (baselines hold a
    /// single queue labelled with their primary lane).
    labels: Vec<LaneId>,
    cap: usize,
    shed: ShedPolicy,
    shed_out: Vec<(LaneId, Task)>,
    seq: u64,
}

impl PolicyQueues {
    /// Build the storage from `(reported lane id, queue flavour)` pairs.
    /// `cap == 0` disables shedding (unbounded queues, the historical
    /// behaviour — bit-identical dispatch).
    pub fn new(queues: Vec<(LaneId, LaneQ)>, cap: usize, shed: ShedPolicy) -> PolicyQueues {
        let (labels, queues): (Vec<LaneId>, Vec<LaneQ>) = queues.into_iter().unzip();
        PolicyQueues { queues, labels, cap, shed, shed_out: Vec::new(), seq: 0 }
    }

    /// Reconfigure overload admission control (used by policy builders
    /// whose constructors predate the shed knobs).
    pub fn set_overload(&mut self, cap: usize, shed: ShedPolicy) {
        self.cap = cap;
        self.shed = shed;
    }

    /// Number of lane queues.
    pub fn n_lanes(&self) -> usize {
        self.queues.len()
    }

    /// One lane queue.
    pub fn lane(&self, idx: usize) -> &LaneQ {
        &self.queues[idx]
    }

    /// One lane queue, mutably.
    pub fn lane_mut(&mut self, idx: usize) -> &mut LaneQ {
        &mut self.queues[idx]
    }

    /// The [`UpQueue`] of lane `idx`; panics if the lane is not UP-kind.
    pub fn up_mut(&mut self, idx: usize) -> &mut UpQueue {
        match &mut self.queues[idx] {
            LaneQ::Up(q) => q,
            _ => panic!("lane {idx} is not an UP queue"),
        }
    }

    /// Queued tasks on lane `idx`.
    pub fn len(&self, idx: usize) -> usize {
        self.queues[idx].len()
    }

    /// Queued tasks across all lanes.
    pub fn total_len(&self) -> usize {
        self.queues.iter().map(LaneQ::len).sum()
    }

    /// Is every lane queue empty?
    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Tasks shed since the last call, with the lane that shed them.
    pub fn take_shed(&mut self) -> Vec<(LaneId, Task)> {
        std::mem::take(&mut self.shed_out)
    }

    /// Admit `task` into lane `idx`, shedding per the configured policy
    /// if the lane is at capacity. Shedding evaluates priorities at the
    /// incoming task's arrival time (the push instant on the engine
    /// clock); the victim may be the incoming task itself.
    pub fn push(&mut self, idx: usize, task: Task) {
        if self.cap > 0 && self.queues[idx].len() >= self.cap {
            match self.shed_one(idx, &task) {
                None => {
                    // the newcomer is the worst of the lot
                    self.shed_out.push((self.labels[idx], task));
                    return;
                }
                Some(victim) => self.shed_out.push((self.labels[idx], victim)),
            }
        }
        self.insert(idx, task);
    }

    /// Re-admit a task the policy itself took out and put back
    /// (consolidation leftovers). Never sheds: a re-insert cannot push
    /// the lane above its pre-pop depth.
    pub fn reinsert(&mut self, idx: usize, task: Task) {
        self.insert(idx, task);
    }

    fn insert(&mut self, idx: usize, task: Task) {
        let seq = self.seq;
        self.seq += 1;
        match &mut self.queues[idx] {
            LaneQ::Fifo(q) => q.push_back(task),
            LaneQ::Keyed { key, queue } => {
                // binary insert keeps the queue ordered; ties break by
                // arrival, equals go after (stable wrt insertion).
                // total_cmp keeps the order total even for NaN keys.
                let k = key(&task);
                let pos = queue.partition_point(|t| {
                    key(t).total_cmp(&k).then(t.arrival.total_cmp(&task.arrival)).is_le()
                });
                queue.insert(pos, task);
            }
            LaneQ::Up(q) => q.insert(task, seq),
        }
    }

    /// Pick and remove the shed victim from an at-cap lane, or return
    /// `None` when the incoming task itself is the victim.
    fn shed_one(&mut self, idx: usize, incoming: &Task) -> Option<Task> {
        match self.shed {
            ShedPolicy::Length => {
                let (at, worst_u) = match &self.queues[idx] {
                    LaneQ::Fifo(q) => {
                        worst_len_at(q.iter())?
                    }
                    LaneQ::Keyed { queue, .. } => worst_len_at(queue.iter())?,
                    LaneQ::Up(q) => {
                        let (r, u) = q.worst_by_length()?;
                        if incoming.uncertainty.total_cmp(&u) != Ordering::Less {
                            return None; // newcomer is longest (ties: latest loses)
                        }
                        return Some(self.up_mut(idx).remove_at(r));
                    }
                };
                if incoming.uncertainty.total_cmp(&worst_u) != Ordering::Less {
                    return None;
                }
                self.remove_index(idx, at)
            }
            ShedPolicy::Priority => match &self.queues[idx] {
                // FIFO priority is arrival order: the newcomer is by
                // definition the lowest-priority task — tail drop
                LaneQ::Fifo(_) => None,
                LaneQ::Keyed { key, queue } => {
                    // dispatch order is front-first: the worst task is
                    // the back; the newcomer loses ties (it would be
                    // inserted after its equals)
                    let back = queue.last()?;
                    let newcomer_worse = key(incoming)
                        .total_cmp(&key(back))
                        .then(incoming.arrival.total_cmp(&back.arrival))
                        != Ordering::Less;
                    if newcomer_worse {
                        None
                    } else {
                        let last = queue.len() - 1;
                        self.remove_index(idx, last)
                    }
                }
                LaneQ::Up(q) => {
                    let now = incoming.arrival;
                    let (r, wk, wa) = q.worst_by_priority(now)?;
                    let k_in = q.priority_of(incoming, now);
                    // the newcomer would carry the latest seq, so it
                    // loses any full tie
                    let newcomer_better = matches!(
                        k_in.total_cmp(&wk).then(wa.total_cmp(&incoming.arrival)),
                        Ordering::Greater
                    );
                    if newcomer_better {
                        Some(self.up_mut(idx).remove_at(r))
                    } else {
                        None
                    }
                }
            },
        }
    }

    fn remove_index(&mut self, idx: usize, at: usize) -> Option<Task> {
        match &mut self.queues[idx] {
            LaneQ::Fifo(q) => q.remove(at),
            LaneQ::Keyed { queue, .. } => Some(queue.remove(at)),
            LaneQ::Up(_) => unreachable!("UP victims are removed by EntryRef"),
        }
    }

    /// Pop the first `n` tasks of lane `idx` in stored order (FIFO /
    /// key-sorted lanes).
    pub fn pop_front(&mut self, idx: usize, n: usize) -> Vec<Task> {
        match &mut self.queues[idx] {
            LaneQ::Fifo(q) => q.drain(..n).collect(),
            LaneQ::Keyed { queue, .. } => queue.drain(..n).collect(),
            LaneQ::Up(_) => panic!("UP lanes pop via pop_top/Selector"),
        }
    }

    /// Drain every task of lane `idx` (lane retirement).
    pub fn drain_lane(&mut self, idx: usize) -> Vec<Task> {
        match &mut self.queues[idx] {
            LaneQ::Fifo(q) => q.drain(..).collect(),
            LaneQ::Keyed { queue, .. } => queue.drain(..).collect(),
            LaneQ::Up(q) => q.drain_all(),
        }
    }
}

/// Index and uncertainty of the longest-predicted task in an iterator
/// (ties: latest index — the most recently inserted for insertion-
/// ordered queues).
fn worst_len_at<'a>(tasks: impl Iterator<Item = &'a Task>) -> Option<(usize, f64)> {
    let mut worst: Option<(usize, f64)> = None;
    for (i, t) in tasks.enumerate() {
        let worse = match &worst {
            None => true,
            Some((_, wu)) => t.uncertainty.total_cmp(wu) != Ordering::Less,
        };
        if worse {
            worst = Some((i, t.uncertainty));
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::task::test_task;
    use crate::util::prop::check_result;
    use crate::util::rng::Pcg64;

    /// The historical `UaSched::sort_queue` oracle: recompute every key,
    /// stable full sort `(p desc, arrival asc)`, drain from the front.
    /// Residual ties keep the vec's physical order, exactly like the
    /// old in-place sort between pops.
    fn oracle_pop(
        queue: &mut Vec<Task>,
        params: &SchedParams,
        eta: f64,
        now: f64,
        k: usize,
    ) -> Vec<Task> {
        let mut keyed: Vec<(f64, Task)> = queue
            .drain(..)
            .map(|t| (up_priority(&t, params, eta, now), t))
            .collect();
        keyed.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.arrival.total_cmp(&b.1.arrival)));
        let mut sorted: Vec<Task> = keyed.into_iter().map(|(_, t)| t).collect();
        let rest = sorted.split_off(k.min(sorted.len()));
        *queue = rest;
        sorted
    }

    fn gen_task(rng: &mut Pcg64, id: u64, now: f64) -> Task {
        let arrival = (now - rng.f64() * 0.5).max(0.0);
        let pp = if rng.f64() < 0.15 {
            now - rng.f64() * 4.0 // already (possibly deeply) overdue
        } else {
            now + rng.f64() * 6.0
        };
        let u = if rng.f64() < 0.1 {
            96.0 + rng.f64() * 40.0 // beyond u_scale: exercises the clamp
        } else {
            4.0 + rng.f64() * 92.0
        };
        test_task(id, arrival, pp, u)
    }

    fn ids(tasks: &[Task]) -> Vec<u64> {
        tasks.iter().map(|t| t.id).collect()
    }

    fn run_trace(seed: u64) -> Result<(), String> {
        let mut rng = Pcg64::with_stream(0xBEEF ^ seed, seed);
        let params = SchedParams {
            alpha: [0.0, 0.5, 1.0, 1.7][rng.range_usize(0, 4)],
            min_slack: [1e-3, 0.25][rng.range_usize(0, 2)],
            ..Default::default()
        };
        let eta = 0.008;
        let mut q = UpQueue::new(params.clone(), eta);
        let mut oracle: Vec<Task> = Vec::new();
        let mut seq = 0u64;
        let mut now = 0.0f64;
        let mut next_id = 0u64;
        let mut round = 0;
        while round < 30 || !oracle.is_empty() {
            round += 1;
            if round > 400 {
                return Err("trace failed to drain".into());
            }
            if round <= 30 {
                for _ in 0..rng.range_usize(0, 7) {
                    let t = if !oracle.is_empty() && rng.f64() < 0.25 {
                        // duplicate (arrival, d, u) under a fresh id: the
                        // stable-sort tie the seq counter must replicate
                        let src = &oracle[rng.range_usize(0, oracle.len())];
                        test_task(next_id, src.arrival, src.priority_point, src.uncertainty)
                    } else {
                        gen_task(&mut rng, next_id, now)
                    };
                    next_id += 1;
                    q.insert(t.clone(), seq);
                    seq += 1;
                    oracle.push(t);
                }
            }
            // occasional big jumps: whole buckets cross into overdue at once
            now += rng.f64() * if rng.f64() < 0.2 { 5.0 } else { 0.8 };
            let k = rng.range_usize(1, 9);
            let got = q.pop_top(now, k);
            let want = oracle_pop(&mut oracle, &params, eta, now, k);
            if ids(&got) != ids(&want) {
                return Err(format!(
                    "round {round} t={now:.4}: got {:?}, want {:?}",
                    ids(&got),
                    ids(&want)
                ));
            }
            if q.len() != oracle.len() {
                return Err(format!(
                    "round {round}: len {} != oracle {}",
                    q.len(),
                    oracle.len()
                ));
            }
        }
        Ok(())
    }

    #[test]
    fn prop_pop_order_matches_keyed_sort_oracle() {
        check_result("up-queue-vs-oracle", 60, |rng| rng.next_u64(), |&seed| run_trace(seed));
    }

    #[test]
    fn prop_worst_by_priority_is_oracle_tail() {
        check_result("worst-vs-oracle-tail", 40, |rng| rng.next_u64(), |&seed| {
            let mut rng = Pcg64::with_stream(0xFACE ^ seed, seed);
            let params = SchedParams {
                alpha: [0.0, 1.0, 1.7][rng.range_usize(0, 3)],
                ..Default::default()
            };
            let eta = 0.008;
            let mut q = UpQueue::new(params.clone(), eta);
            let mut oracle = Vec::new();
            let mut now = 0.0;
            for i in 0..rng.range_usize(1, 40) as u64 {
                let t = gen_task(&mut rng, i, now);
                q.insert(t.clone(), i);
                oracle.push(t);
                now += rng.f64() * 0.3;
            }
            if rng.f64() < 0.5 {
                q.promote(now); // the scan must not care about promotion state
            }
            let all = oracle_pop(&mut oracle, &params, eta, now, usize::MAX);
            let want = all.last().unwrap().id;
            let (r, _, _) = q.worst_by_priority(now).unwrap();
            let got = q.task(r).id;
            if got != want {
                return Err(format!("worst: got {got}, want {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn fifo_order_pop_returns_insertion_order() {
        let mut q = UpQueue::new(SchedParams::default(), 0.01);
        for i in 0..10u64 {
            // priorities deliberately anti-correlated with insertion order
            q.insert(test_task(i, i as f64 * 0.1, 5.0 + (10 - i) as f64, 20.0 + i as f64), i);
        }
        assert_eq!(ids(&q.pop_fifo_order(4)), vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 6);
        assert_eq!(ids(&q.pop_fifo_order(100)), vec![4, 5, 6, 7, 8, 9]);
        assert!(q.is_empty());
    }

    #[test]
    fn min_arrival_tracks_queue_contents() {
        let mut q = UpQueue::new(SchedParams::default(), 0.01);
        assert_eq!(q.min_arrival(), f64::INFINITY);
        q.insert(test_task(1, 3.0, 9.0, 20.0), 0);
        q.insert(test_task(2, 1.5, 4.0, 20.0), 1);
        assert_eq!(q.min_arrival(), 1.5);
    }

    fn pq_up(cap: usize, shed: ShedPolicy) -> PolicyQueues {
        PolicyQueues::new(
            vec![(LaneId(0), LaneQ::up(SchedParams::default(), 0.01))],
            cap,
            shed,
        )
    }

    #[test]
    fn cap_zero_never_sheds() {
        let mut pq = pq_up(0, ShedPolicy::Priority);
        for i in 0..100 {
            pq.push(0, test_task(i, 0.0, 5.0, 20.0));
        }
        assert_eq!(pq.len(0), 100);
        assert!(pq.take_shed().is_empty());
    }

    #[test]
    fn fifo_priority_shed_is_tail_drop() {
        let mut pq = PolicyQueues::new(vec![(LaneId(1), LaneQ::fifo())], 3, ShedPolicy::Priority);
        for i in 0..5 {
            pq.push(0, test_task(i, i as f64, 5.0, 20.0));
        }
        assert_eq!(pq.len(0), 3);
        let shed: Vec<(usize, u64)> =
            pq.take_shed().iter().map(|(l, t)| (l.0, t.id)).collect();
        assert_eq!(shed, vec![(1, 3), (1, 4)], "newcomers drop, labelled with the lane id");
    }

    #[test]
    fn up_priority_shed_drops_lowest_priority() {
        let mut pq = pq_up(2, ShedPolicy::Priority);
        pq.push(0, test_task(1, 0.0, 50.0, 20.0)); // loose deadline: lowest priority
        pq.push(0, test_task(2, 0.0, 5.0, 20.0));
        pq.push(0, test_task(3, 0.1, 2.0, 20.0)); // tight newcomer evicts the loose task
        let shed = pq.take_shed();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].1.id, 1);
        pq.push(0, test_task(4, 0.2, 500.0, 20.0)); // hopeless newcomer sheds itself
        assert_eq!(pq.take_shed()[0].1.id, 4);
        assert_eq!(pq.len(0), 2);
    }

    #[test]
    fn length_shed_drops_longest_prediction() {
        let mut pq = pq_up(2, ShedPolicy::Length);
        pq.push(0, test_task(1, 0.0, 5.0, 90.0));
        pq.push(0, test_task(2, 0.0, 5.0, 10.0));
        pq.push(0, test_task(3, 0.1, 5.0, 40.0)); // evicts u=90
        assert_eq!(pq.take_shed()[0].1.id, 1);
        pq.push(0, test_task(4, 0.2, 5.0, 95.0)); // longest itself -> shed
        assert_eq!(pq.take_shed()[0].1.id, 4);
        assert_eq!(ids(&pq.up_mut(0).pop_top(1.0, 10)), vec![2, 3]);
    }

    #[test]
    fn keyed_priority_shed_drops_back_of_queue() {
        let mut pq = PolicyQueues::new(
            vec![(LaneId(0), LaneQ::keyed(Box::new(|t: &Task| t.uncertainty)))],
            2,
            ShedPolicy::Priority,
        );
        pq.push(0, test_task(1, 0.0, 5.0, 10.0));
        pq.push(0, test_task(2, 0.1, 5.0, 50.0));
        pq.push(0, test_task(3, 0.2, 5.0, 30.0)); // beats the back (u=50)
        assert_eq!(pq.take_shed()[0].1.id, 2);
        pq.push(0, test_task(4, 0.3, 5.0, 99.0)); // worse than the back: sheds itself
        assert_eq!(pq.take_shed()[0].1.id, 4);
        assert_eq!(ids(&pq.pop_front(0, 2)), vec![1, 3]);
    }

    #[test]
    fn reinsert_bypasses_the_cap() {
        let mut pq = pq_up(2, ShedPolicy::Priority);
        pq.push(0, test_task(1, 0.0, 5.0, 20.0));
        pq.push(0, test_task(2, 0.0, 6.0, 20.0));
        let popped = pq.up_mut(0).pop_top(0.5, 1);
        pq.reinsert(0, popped.into_iter().next().unwrap());
        assert_eq!(pq.len(0), 2);
        assert!(pq.take_shed().is_empty());
    }
}
