//! The unit of work: one LM request with its uncertainty metadata and
//! (optionally) a service-level-objective class.

/// Service-level-objective class of a request. A class carries no
/// scheduler machinery of its own: class deadlines are encoded in the
/// task's priority point (`d_J = arrival + deadline`), which the UP
/// priority (Eq. 3) already consumes — so classed and classless tasks
/// flow through identical scheduling code, and per-class attainment is
/// pure accounting over the outcomes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SloClass {
    /// No declared SLO — the historical default; priority points come
    /// from `deadline_base + phi * |J|`. Reports and JSONL exports omit
    /// class columns for these, keeping classless runs bit-identical to
    /// pre-SLO behaviour.
    #[default]
    Standard,
    /// Latency-sensitive (chat-style) traffic with a tight deadline.
    Interactive,
    /// Throughput-oriented background traffic with a loose deadline.
    Batch,
}

impl SloClass {
    /// Lower-case display/report label.
    pub fn label(&self) -> &'static str {
        match self {
            SloClass::Standard => "standard",
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }

    /// Parse a report/CLI token produced by [`label`](Self::label).
    pub fn parse(s: &str) -> anyhow::Result<SloClass> {
        match s {
            "standard" => Ok(SloClass::Standard),
            "interactive" => Ok(SloClass::Interactive),
            "batch" => Ok(SloClass::Batch),
            other => Err(anyhow::anyhow!(
                "unknown SLO class '{other}' (standard | interactive | batch)"
            )),
        }
    }
}

/// A scheduled LM request (paper's task J).
#[derive(Clone, Debug)]
pub struct Task {
    /// Unique task id.
    pub id: u64,
    /// Raw input text (kept for diagnostics; execution uses `prompt`).
    pub text: String,
    /// Encoded prompt (empty in pure-simulation runs).
    pub prompt: Vec<i32>,
    /// Arrival time r_J (seconds on the engine clock).
    pub arrival: f64,
    /// Priority point d_J (absolute seconds): user deadline when given,
    /// else r_J + phi_f * |J| (Sec. IV-B).
    pub priority_point: f64,
    /// Uncertainty score u_J: predicted output length in tokens (Eq. 1).
    pub uncertainty: f64,
    /// Ground-truth output length for the serving model (length oracle).
    pub true_len: usize,
    /// Input length in tokens.
    pub input_len: usize,
    /// Primary uncertainty type (diagnostics / figures).
    pub utype: String,
    /// Whether this task was adversarially crafted (Sec. V-G).
    pub malicious: bool,
    /// How many times consolidation has re-queued this task (bounded-
    /// deferral anti-starvation, see uasched.rs).
    pub deferrals: u32,
    /// Service-level-objective class; [`SloClass::Standard`] for
    /// classless (historical) traffic. The class deadline is already
    /// folded into `priority_point`.
    pub slo: SloClass,
}

impl Task {
    /// Estimated slack zeta_J = d_J - r_J - eta_f * u_J (Eq. 2 denominator)
    /// evaluated at arrival.
    pub fn slack(&self, eta: f64) -> f64 {
        self.slack_at(eta, self.arrival)
    }

    /// Slack at scheduling time `now`: the remaining time until the
    /// priority point minus the estimated execution time.
    pub fn slack_at(&self, eta: f64, now: f64) -> f64 {
        self.priority_point - now - eta * self.uncertainty
    }
}

/// Minimal task constructor for unit tests (`true_len` mirrors the
/// uncertainty, text/prompt empty).
#[cfg(test)]
pub fn test_task(id: u64, arrival: f64, priority_point: f64, uncertainty: f64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival,
        priority_point,
        uncertainty,
        true_len: uncertainty.max(1.0) as usize,
        input_len: 8,
        utype: "plain".into(),
        malicious: false,
        deferrals: 0,
        slo: SloClass::Standard,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slack_formula() {
        let t = test_task(1, 10.0, 13.0, 20.0);
        // d - r - eta*u = 13 - 10 - 0.05*20 = 2.0
        assert!((t.slack(0.05) - 2.0).abs() < 1e-12);
    }
}
