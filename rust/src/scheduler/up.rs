//! Uncertainty-aware Prioritization (UP) — Eq. 2 and Eq. 3.

use crate::config::SchedParams;

use super::task::Task;

/// Slack-based priority (Eq. 2): p = 1 / zeta, with zeta evaluated at
/// scheduling time `now` ("the remaining time until the priority
/// point") so waiting tasks age upward and cannot starve.
pub fn slack_priority(task: &Task, eta: f64, now: f64, min_slack: f64) -> f64 {
    1.0 / task.slack_at(eta, now).max(min_slack)
}

/// UP priority (Eq. 3): p = (1 - alpha * u_hat) / zeta, where u_hat is
/// the uncertainty score normalised to [0, 1] by `u_scale` (the paper's
/// formula mixes token counts and seconds; normalising the numerator
/// keeps alpha's 0..2 sweep meaningful — see DESIGN.md).
///
/// The slack is evaluated at scheduling time `now` (the paper's
/// "remaining time until the priority point"), so priorities age: a task
/// left waiting climbs toward the front and cannot starve. A task past
/// its priority point (zeta <= 0) saturates at the maximal priority for
/// its numerator sign instead of dividing by a negative number.
pub fn up_priority(task: &Task, params: &SchedParams, eta: f64, now: f64) -> f64 {
    let u_hat = (task.uncertainty / params.u_scale).clamp(0.0, 1.0);
    let numerator = 1.0 - params.alpha * u_hat;
    let raw_slack = task.slack_at(eta, now);
    if raw_slack >= params.min_slack {
        return numerator / raw_slack;
    }
    // Overdue: clamping alone would tie every late task at the same
    // slack, letting low-uncertainty arrivals starve a long-waiting
    // high-uncertainty task forever. Add a lateness term that grows
    // without bound so every task eventually reaches the front.
    let lateness = params.min_slack - raw_slack;
    (numerator + lateness) / params.min_slack
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::task::test_task;

    fn params() -> SchedParams {
        SchedParams::default()
    }

    #[test]
    fn lower_uncertainty_wins_at_equal_slack() {
        let p = params();
        let hi = test_task(1, 0.0, 5.0, 80.0);
        let lo = test_task(2, 0.0, 5.0 - 0.05 * (80.0 - 10.0), 10.0); // equalise slack
        assert!((lo.slack(0.05) - hi.slack(0.05)).abs() < 1e-9);
        assert!(up_priority(&lo, &p, 0.05, 0.0) > up_priority(&hi, &p, 0.05, 0.0));
    }

    #[test]
    fn tighter_slack_wins_at_equal_uncertainty() {
        let p = params();
        let tight = test_task(1, 0.0, 1.0, 20.0);
        let loose = test_task(2, 0.0, 9.0, 20.0);
        assert!(up_priority(&tight, &p, 0.05, 0.0) > up_priority(&loose, &p, 0.05, 0.0));
    }

    #[test]
    fn overdue_task_saturates_not_flips() {
        let p = params();
        // d < r: negative slack must clamp, yielding a huge positive
        // priority (for positive numerator), not a negative one.
        let overdue = test_task(1, 10.0, 9.0, 10.0);
        let pr = up_priority(&overdue, &p, 0.05, 10.0);
        assert!(pr > 0.0 && pr.is_finite());
        let fresh = test_task(2, 10.0, 20.0, 10.0);
        assert!(pr > up_priority(&fresh, &p, 0.05, 10.0));
    }

    #[test]
    fn alpha_zero_reduces_to_slack_priority() {
        let mut p = params();
        p.alpha = 0.0;
        let t = test_task(1, 0.0, 3.0, 40.0);
        let up = up_priority(&t, &p, 0.05, 0.0);
        let slack = slack_priority(&t, 0.05, 0.0, p.min_slack);
        assert!((up - slack).abs() < 1e-12);
    }

    #[test]
    fn large_alpha_deprioritises_uncertain_tasks() {
        let mut p = params();
        p.alpha = 2.0;
        let certain = test_task(1, 0.0, 5.0, 5.0);
        let uncertain = test_task(2, 0.0, 5.0, 90.0); // u_hat ~ 0.94 -> numerator < 0
        assert!(up_priority(&uncertain, &p, 0.05, 0.0) < 0.0);
        assert!(up_priority(&certain, &p, 0.05, 0.0) > up_priority(&uncertain, &p, 0.05, 0.0));
    }
}

#[cfg(test)]
mod aging_tests {
    use super::*;
    use crate::scheduler::task::test_task;

    #[test]
    fn waiting_raises_priority() {
        let p = SchedParams::default();
        let t = test_task(1, 0.0, 6.0, 20.0);
        let fresh = up_priority(&t, &p, 0.05, 0.0);
        let aged = up_priority(&t, &p, 0.05, 5.0);
        assert!(aged > fresh, "aging must raise priority: {fresh} -> {aged}");
    }
}
