//! Uncertainty-oblivious / single-signal baselines (Sec. V-B):
//! FIFO, HPF (highest priority-point first), LUF (least uncertainty
//! first), MUF (maximum uncertainty first). All use fixed-size batching
//! and dispatch only on the fleet's primary lane — baselines do not
//! offload.

use std::collections::VecDeque;

use super::lane::LaneId;
use super::policy::{Batch, Policy};
use super::task::Task;

/// First-In-First-Out with fixed-size batches.
pub struct Fifo {
    queue: VecDeque<Task>,
    batch_size: usize,
    primary: LaneId,
}

impl Fifo {
    /// FIFO on the default two-lane fleet's accelerator lane.
    pub fn new(batch_size: usize) -> Fifo {
        Fifo::new_on(batch_size, LaneId::GPU)
    }

    /// FIFO dispatching on the given primary lane.
    pub fn new_on(batch_size: usize, primary: LaneId) -> Fifo {
        Fifo { queue: VecDeque::new(), batch_size: batch_size.max(1), primary }
    }
}

impl Policy for Fifo {
    fn name(&self) -> String {
        "FIFO".into()
    }

    fn push(&mut self, task: Task) {
        self.queue.push_back(task);
    }

    fn pop_batch(&mut self, lane: LaneId, _now: f64, force: bool) -> Option<Batch> {
        if lane != self.primary {
            return None; // baselines are uncertainty-oblivious: primary lane only
        }
        if self.queue.is_empty() || (!force && self.queue.len() < self.batch_size) {
            return None;
        }
        let n = self.queue.len().min(self.batch_size);
        let tasks = self.queue.drain(..n).collect();
        Some(Batch { lane: self.primary, tasks })
    }

    fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

/// Sorted-queue policy: keeps tasks ordered by a key, dispatches the
/// first `batch_size` (tasks with similar keys batch together).
struct Sorted<K: Fn(&Task) -> f64 + Send> {
    name: &'static str,
    queue: Vec<Task>,
    key: K,
    batch_size: usize,
    primary: LaneId,
}

impl<K: Fn(&Task) -> f64 + Send> Sorted<K> {
    fn new(name: &'static str, key: K, batch_size: usize, primary: LaneId) -> Self {
        Sorted { name, queue: Vec::new(), key, batch_size: batch_size.max(1), primary }
    }
}

impl<K: Fn(&Task) -> f64 + Send> Policy for Sorted<K> {
    fn name(&self) -> String {
        self.name.into()
    }

    fn push(&mut self, task: Task) {
        // binary insert keeps the queue ordered; ties break by arrival.
        // total_cmp keeps the order total even for NaN keys (a NaN
        // comparison returning false would silently break the invariant
        // the binary search relies on).
        let k = (self.key)(&task);
        let pos = self.queue.partition_point(|t| {
            (self.key)(t)
                .total_cmp(&k)
                .then(t.arrival.total_cmp(&task.arrival))
                .is_le()
        });
        self.queue.insert(pos, task);
    }

    fn pop_batch(&mut self, lane: LaneId, _now: f64, force: bool) -> Option<Batch> {
        if lane != self.primary {
            return None;
        }
        if self.queue.is_empty() || (!force && self.queue.len() < self.batch_size) {
            return None;
        }
        let n = self.queue.len().min(self.batch_size);
        let tasks = self.queue.drain(..n).collect();
        Some(Batch { lane: self.primary, tasks })
    }

    fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

/// Highest Priority-Point First: earliest d_J dispatches first.
pub struct Hpf(Sorted<fn(&Task) -> f64>);

impl Hpf {
    /// HPF on the default two-lane fleet's accelerator lane.
    pub fn new(batch_size: usize) -> Hpf {
        Hpf::new_on(batch_size, LaneId::GPU)
    }

    /// HPF dispatching on the given primary lane.
    pub fn new_on(batch_size: usize, primary: LaneId) -> Hpf {
        Hpf(Sorted::new("HPF", |t: &Task| t.priority_point, batch_size, primary))
    }
}

impl Policy for Hpf {
    fn name(&self) -> String {
        self.0.name()
    }
    fn push(&mut self, task: Task) {
        self.0.push(task)
    }
    fn pop_batch(&mut self, lane: LaneId, now: f64, force: bool) -> Option<Batch> {
        self.0.pop_batch(lane, now, force)
    }
    fn queue_len(&self) -> usize {
        self.0.queue_len()
    }
}

/// Least Uncertainty First.
pub struct Luf(Sorted<fn(&Task) -> f64>);

impl Luf {
    /// LUF on the default two-lane fleet's accelerator lane.
    pub fn new(batch_size: usize) -> Luf {
        Luf::new_on(batch_size, LaneId::GPU)
    }

    /// LUF dispatching on the given primary lane.
    pub fn new_on(batch_size: usize, primary: LaneId) -> Luf {
        Luf(Sorted::new("LUF", |t: &Task| t.uncertainty, batch_size, primary))
    }
}

impl Policy for Luf {
    fn name(&self) -> String {
        self.0.name()
    }
    fn push(&mut self, task: Task) {
        self.0.push(task)
    }
    fn pop_batch(&mut self, lane: LaneId, now: f64, force: bool) -> Option<Batch> {
        self.0.pop_batch(lane, now, force)
    }
    fn queue_len(&self) -> usize {
        self.0.queue_len()
    }
}

/// Maximum Uncertainty First.
pub struct Muf(Sorted<fn(&Task) -> f64>);

impl Muf {
    /// MUF on the default two-lane fleet's accelerator lane.
    pub fn new(batch_size: usize) -> Muf {
        Muf::new_on(batch_size, LaneId::GPU)
    }

    /// MUF dispatching on the given primary lane.
    pub fn new_on(batch_size: usize, primary: LaneId) -> Muf {
        Muf(Sorted::new("MUF", |t: &Task| -t.uncertainty, batch_size, primary))
    }
}

impl Policy for Muf {
    fn name(&self) -> String {
        self.0.name()
    }
    fn push(&mut self, task: Task) {
        self.0.push(task)
    }
    fn pop_batch(&mut self, lane: LaneId, now: f64, force: bool) -> Option<Batch> {
        self.0.pop_batch(lane, now, force)
    }
    fn queue_len(&self) -> usize {
        self.0.queue_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::task::test_task;

    #[test]
    fn fifo_preserves_arrival_order() {
        let mut f = Fifo::new(2);
        f.push(test_task(1, 0.0, 10.0, 5.0));
        f.push(test_task(2, 1.0, 5.0, 50.0));
        f.push(test_task(3, 2.0, 1.0, 20.0));
        let b = f.pop_batch(LaneId::GPU, 0.0, false).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(f.queue_len(), 1);
    }

    #[test]
    fn fifo_waits_for_full_batch_unless_forced() {
        let mut f = Fifo::new(4);
        f.push(test_task(1, 0.0, 1.0, 1.0));
        assert!(f.pop_batch(LaneId::GPU, 0.0, false).is_none());
        let b = f.pop_batch(LaneId::GPU, 0.0, true).unwrap();
        assert_eq!(b.tasks.len(), 1);
    }

    #[test]
    fn baselines_only_dispatch_on_their_primary_lane() {
        let mut f = Fifo::new_on(1, LaneId(2));
        f.push(test_task(1, 0.0, 1.0, 1.0));
        assert!(f.pop_batch(LaneId(0), 0.0, true).is_none());
        assert!(f.pop_batch(LaneId(1), 0.0, true).is_none());
        let b = f.pop_batch(LaneId(2), 0.0, true).unwrap();
        assert_eq!(b.lane, LaneId(2));
    }

    #[test]
    fn hpf_orders_by_priority_point() {
        let mut h = Hpf::new(2);
        h.push(test_task(1, 0.0, 9.0, 5.0));
        h.push(test_task(2, 0.0, 3.0, 5.0));
        h.push(test_task(3, 0.0, 6.0, 5.0));
        let b = h.pop_batch(LaneId::GPU, 0.0, true).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn luf_orders_ascending_uncertainty() {
        let mut l = Luf::new(3);
        l.push(test_task(1, 0.0, 1.0, 40.0));
        l.push(test_task(2, 0.0, 1.0, 10.0));
        l.push(test_task(3, 0.0, 1.0, 25.0));
        let b = l.pop_batch(LaneId::GPU, 0.0, false).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3, 1]);
    }

    #[test]
    fn muf_orders_descending_uncertainty() {
        let mut m = Muf::new(3);
        m.push(test_task(1, 0.0, 1.0, 40.0));
        m.push(test_task(2, 0.0, 1.0, 10.0));
        m.push(test_task(3, 0.0, 1.0, 25.0));
        let b = m.pop_batch(LaneId::GPU, 0.0, false).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 3, 2]);
    }

    #[test]
    fn sorted_ties_break_by_arrival() {
        let mut l = Luf::new(4);
        l.push(test_task(2, 1.0, 1.0, 10.0));
        l.push(test_task(1, 0.0, 1.0, 10.0));
        let b = l.pop_batch(LaneId::GPU, 0.0, true).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 2]);
    }
}
