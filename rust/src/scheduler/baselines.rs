//! Uncertainty-oblivious / single-signal baselines (Sec. V-B):
//! FIFO, HPF (highest priority-point first), LUF (least uncertainty
//! first), MUF (maximum uncertainty first). All use fixed-size batching
//! and dispatch only on the fleet's primary lane — baselines do not
//! offload.
//!
//! Storage, insertion order, and overload shedding live in the shared
//! [`PolicyQueues`] helper; what remains here is each baseline's
//! ordering key and the fixed-batch admission gate.

use crate::config::{SchedParams, ShedPolicy};

use super::lane::LaneId;
use super::policy::{Batch, Policy, WHOLE_BATCH};
use super::queue::{LaneQ, PolicyQueues};
use super::task::Task;

/// The shared single-lane pop: fixed-size batches off the front of the
/// one queue, primary lane only. With a stepped `free` below the batch
/// size, the overflow is re-admitted (FIFO: back of the queue; sorted:
/// its key position) — the historical `pop_fill` adapter semantics.
fn single_lane_pop(
    queues: &mut PolicyQueues,
    primary: LaneId,
    batch_size: usize,
    lane: LaneId,
    force: bool,
    free: usize,
) -> Option<Batch> {
    if lane != primary || free == 0 {
        return None; // baselines are uncertainty-oblivious: primary lane only
    }
    let len = queues.len(0);
    if len == 0 || (!force && len < batch_size) {
        return None;
    }
    let n = len.min(batch_size);
    let mut tasks = queues.pop_front(0, n);
    if free < tasks.len() {
        for task in tasks.split_off(free) {
            queues.reinsert(0, task);
        }
    }
    Some(Batch { lane, tasks })
}

/// First-In-First-Out with fixed-size batches.
pub struct Fifo {
    queues: PolicyQueues,
    batch_size: usize,
    primary: LaneId,
}

impl Fifo {
    /// FIFO on the default two-lane fleet's accelerator lane.
    pub fn new(batch_size: usize) -> Fifo {
        Fifo::new_on(batch_size, LaneId::GPU)
    }

    /// FIFO dispatching on the given primary lane (unbounded queue).
    pub fn new_on(batch_size: usize, primary: LaneId) -> Fifo {
        Fifo {
            queues: PolicyQueues::new(vec![(primary, LaneQ::fifo())], 0, ShedPolicy::Priority),
            batch_size: batch_size.max(1),
            primary,
        }
    }

    /// Enable overload admission control from the scheduler params.
    pub fn with_overload(mut self, params: &SchedParams) -> Fifo {
        self.queues.set_overload(params.queue_cap, params.shed);
        self
    }
}

impl Policy for Fifo {
    fn name(&self) -> String {
        "FIFO".into()
    }

    fn push(&mut self, task: Task) {
        self.queues.push(0, task);
    }

    fn pop(&mut self, lane: LaneId, _now: f64, force: bool, free: usize) -> Option<Batch> {
        single_lane_pop(&mut self.queues, self.primary, self.batch_size, lane, force, free)
    }

    fn queue_len(&self) -> usize {
        self.queues.total_len()
    }

    fn take_shed(&mut self) -> Vec<(LaneId, Task)> {
        self.queues.take_shed()
    }
}

/// Sorted-queue policy: keeps tasks ordered by a key, dispatches the
/// first `batch_size` (tasks with similar keys batch together). The
/// named baselines below are constructors for this one type.
pub struct Sorted {
    name: &'static str,
    queues: PolicyQueues,
    batch_size: usize,
    primary: LaneId,
}

impl Sorted {
    fn new(
        name: &'static str,
        key: Box<dyn Fn(&Task) -> f64 + Send>,
        batch_size: usize,
        primary: LaneId,
    ) -> Sorted {
        Sorted {
            name,
            queues: PolicyQueues::new(vec![(primary, LaneQ::keyed(key))], 0, ShedPolicy::Priority),
            batch_size: batch_size.max(1),
            primary,
        }
    }

    /// Enable overload admission control from the scheduler params.
    pub fn with_overload(mut self, params: &SchedParams) -> Sorted {
        self.queues.set_overload(params.queue_cap, params.shed);
        self
    }
}

impl Policy for Sorted {
    fn name(&self) -> String {
        self.name.into()
    }

    fn push(&mut self, task: Task) {
        self.queues.push(0, task);
    }

    fn pop(&mut self, lane: LaneId, _now: f64, force: bool, free: usize) -> Option<Batch> {
        single_lane_pop(&mut self.queues, self.primary, self.batch_size, lane, force, free)
    }

    fn queue_len(&self) -> usize {
        self.queues.total_len()
    }

    fn take_shed(&mut self) -> Vec<(LaneId, Task)> {
        self.queues.take_shed()
    }
}

/// Highest Priority-Point First: earliest d_J dispatches first.
pub struct Hpf;

impl Hpf {
    /// HPF on the default two-lane fleet's accelerator lane.
    pub fn new(batch_size: usize) -> Sorted {
        Hpf::new_on(batch_size, LaneId::GPU)
    }

    /// HPF dispatching on the given primary lane.
    pub fn new_on(batch_size: usize, primary: LaneId) -> Sorted {
        Sorted::new("HPF", Box::new(|t: &Task| t.priority_point), batch_size, primary)
    }
}

/// Least Uncertainty First.
pub struct Luf;

impl Luf {
    /// LUF on the default two-lane fleet's accelerator lane.
    pub fn new(batch_size: usize) -> Sorted {
        Luf::new_on(batch_size, LaneId::GPU)
    }

    /// LUF dispatching on the given primary lane.
    pub fn new_on(batch_size: usize, primary: LaneId) -> Sorted {
        Sorted::new("LUF", Box::new(|t: &Task| t.uncertainty), batch_size, primary)
    }
}

/// Maximum Uncertainty First.
pub struct Muf;

impl Muf {
    /// MUF on the default two-lane fleet's accelerator lane.
    pub fn new(batch_size: usize) -> Sorted {
        Muf::new_on(batch_size, LaneId::GPU)
    }

    /// MUF dispatching on the given primary lane.
    pub fn new_on(batch_size: usize, primary: LaneId) -> Sorted {
        Sorted::new("MUF", Box::new(|t: &Task| -t.uncertainty), batch_size, primary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::task::test_task;

    #[test]
    fn fifo_preserves_arrival_order() {
        let mut f = Fifo::new(2);
        f.push(test_task(1, 0.0, 10.0, 5.0));
        f.push(test_task(2, 1.0, 5.0, 50.0));
        f.push(test_task(3, 2.0, 1.0, 20.0));
        let b = f.pop(LaneId::GPU, 0.0, false, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(f.queue_len(), 1);
    }

    #[test]
    fn fifo_waits_for_full_batch_unless_forced() {
        let mut f = Fifo::new(4);
        f.push(test_task(1, 0.0, 1.0, 1.0));
        assert!(f.pop(LaneId::GPU, 0.0, false, WHOLE_BATCH).is_none());
        let b = f.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.len(), 1);
    }

    #[test]
    fn baselines_only_dispatch_on_their_primary_lane() {
        let mut f = Fifo::new_on(1, LaneId(2));
        f.push(test_task(1, 0.0, 1.0, 1.0));
        assert!(f.pop(LaneId(0), 0.0, true, WHOLE_BATCH).is_none());
        assert!(f.pop(LaneId(1), 0.0, true, WHOLE_BATCH).is_none());
        let b = f.pop(LaneId(2), 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.lane, LaneId(2));
    }

    #[test]
    fn hpf_orders_by_priority_point() {
        let mut h = Hpf::new(2);
        h.push(test_task(1, 0.0, 9.0, 5.0));
        h.push(test_task(2, 0.0, 3.0, 5.0));
        h.push(test_task(3, 0.0, 6.0, 5.0));
        let b = h.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn luf_orders_ascending_uncertainty() {
        let mut l = Luf::new(3);
        l.push(test_task(1, 0.0, 1.0, 40.0));
        l.push(test_task(2, 0.0, 1.0, 10.0));
        l.push(test_task(3, 0.0, 1.0, 25.0));
        let b = l.pop(LaneId::GPU, 0.0, false, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3, 1]);
    }

    #[test]
    fn muf_orders_descending_uncertainty() {
        let mut m = Muf::new(3);
        m.push(test_task(1, 0.0, 1.0, 40.0));
        m.push(test_task(2, 0.0, 1.0, 10.0));
        m.push(test_task(3, 0.0, 1.0, 25.0));
        let b = m.pop(LaneId::GPU, 0.0, false, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 3, 2]);
    }

    #[test]
    fn sorted_ties_break_by_arrival() {
        let mut l = Luf::new(4);
        l.push(test_task(2, 1.0, 1.0, 10.0));
        l.push(test_task(1, 0.0, 1.0, 10.0));
        let b = l.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn stepped_pop_reinserts_overflow_in_order() {
        let mut f = Fifo::new(4);
        for i in 1..=4 {
            f.push(test_task(i, i as f64, 1.0, 1.0));
        }
        // only 2 free slots: the other 2 go back, order intact
        let b = f.pop(LaneId::GPU, 0.0, false, 2).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 2]);
        let b = f.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn capped_fifo_sheds_newcomers() {
        let params = SchedParams { queue_cap: 2, ..Default::default() };
        let mut f = Fifo::new(2).with_overload(&params);
        f.push(test_task(1, 0.0, 1.0, 1.0));
        f.push(test_task(2, 1.0, 1.0, 1.0));
        f.push(test_task(3, 2.0, 1.0, 1.0));
        assert_eq!(f.queue_len(), 2);
        let shed = f.take_shed();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].0, LaneId::GPU);
        assert_eq!(shed[0].1.id, 3);
    }

    #[test]
    fn capped_sorted_sheds_worst_key() {
        let params = SchedParams { queue_cap: 2, ..Default::default() };
        let mut l = Luf::new(2).with_overload(&params);
        l.push(test_task(1, 0.0, 1.0, 90.0));
        l.push(test_task(2, 1.0, 1.0, 10.0));
        l.push(test_task(3, 2.0, 1.0, 30.0)); // evicts the u=90 task
        assert_eq!(l.take_shed()[0].1.id, 1);
        let b = l.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3]);
    }
}
