//! The runtime lane table: a heterogeneous fleet of execution lanes
//! behind one uncertainty-aware queue.
//!
//! Historically the engine hardcoded exactly two lanes
//! (`enum Lane { Gpu, Cpu }`) and the RT-LM offload rule was a `tau`
//! special case inside the scheduler. This module generalises both: a
//! [`LaneSet`] is an ordered table of [`LaneSpec`]s — name, device
//! kind, model variant, batch size, intra-batch workers, and an
//! [`Admission`] predicate — indexed by a dense [`LaneId`]. The paper's
//! strategic CPU offloading (Eq. 4, `u > tau` quarantines to the CPU
//! lane) is exactly the two-lane instance [`LaneSet::two_lane`]: an
//! accelerator fallback lane plus a CPU lane admitting `u > tau`.
//!
//! Routing is deterministic and NaN-safe: a task is claimed by the
//! first non-fallback lane whose predicate admits its uncertainty;
//! anything unclaimed (including NaN scores, which no comparison
//! admits) lands on the first fallback lane — the same place the old
//! `u > tau` test sent it.

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Dense index into a [`LaneSet`] — the engine's per-lane state
/// (`busy`, batch counters, worker channels) is `Vec`-indexed by it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LaneId(/** the dense index */ pub usize);

impl LaneId {
    /// The accelerator lane of the default two-lane convention
    /// ([`LaneSet::two_lane`]); lane 0 is the first fallback lane there.
    pub const GPU: LaneId = LaneId(0);
    /// The quarantine lane of the default two-lane convention.
    pub const CPU: LaneId = LaneId(1);

    /// The dense vector index this id addresses.
    pub fn index(self) -> usize {
        self.0
    }
}

impl std::fmt::Display for LaneId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lane{}", self.0)
    }
}

/// What kind of device a lane models — which latency model and executor
/// shape it gets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneKind {
    /// Batched execution: the whole batch runs fused, every task
    /// completes when the batch does (paper: GPU).
    Accelerator,
    /// Quarantine-style execution: tasks run at batch 1 across an
    /// intra-batch worker pool; the lane frees when the whole batch is
    /// done (paper: CPU cores).
    Cpu,
    /// A lane living in another process: the router's proxy for one
    /// lane of a registered node. Executes whole batches over a framed
    /// TCP connection (`server::wire`); only the `rtlm route` fleet
    /// builds these — the simulator and local backends reject them.
    Remote,
}

impl LaneKind {
    /// Parse the CLI token: `gpu`/`accel`/`accelerator`,
    /// `cpu`/`quarantine`, or `remote` (gossiped lane tables).
    pub fn parse(s: &str) -> Result<LaneKind> {
        Ok(match s {
            "gpu" | "accel" | "accelerator" => LaneKind::Accelerator,
            "cpu" | "quarantine" => LaneKind::Cpu,
            "remote" => LaneKind::Remote,
            other => bail!("unknown lane kind '{other}' (gpu | cpu | remote)"),
        })
    }

    /// The canonical token [`LaneKind::parse`] accepts — used when a
    /// node gossips its lane table over the wire.
    pub fn label(&self) -> &'static str {
        match self {
            LaneKind::Accelerator => "gpu",
            LaneKind::Cpu => "cpu",
            LaneKind::Remote => "remote",
        }
    }
}

/// Per-lane admission predicate over a task's uncertainty score — the
/// generalisation of the paper's `u > tau` offload rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    /// Takes whatever no other lane claimed. Every valid [`LaneSet`]
    /// has at least one fallback lane, so routing is total.
    Fallback,
    /// Claims `u > x` — `Above(tau)` is strategic offloading (Eq. 4).
    Above(f64),
    /// Claims `u <= x` (e.g. a small fast model variant for
    /// low-uncertainty traffic).
    AtMost(f64),
    /// Claims `lo < u <= hi`.
    Band(f64, f64),
    /// Claims nothing — a drained / decommissioned lane.
    Nothing,
}

impl Admission {
    /// Does this predicate claim a task with uncertainty `u`? Fallback
    /// lanes never *claim*; they receive the unclaimed remainder. All
    /// comparisons are false for NaN, so unscorable tasks fall through
    /// to the fallback lane.
    pub fn claims(&self, u: f64) -> bool {
        match *self {
            Admission::Fallback | Admission::Nothing => false,
            Admission::Above(x) => u > x,
            Admission::AtMost(x) => u <= x,
            Admission::Band(lo, hi) => u > lo && u <= hi,
        }
    }

    /// Can this predicate ever claim a (finite) score? `Above(inf)` —
    /// the historical `tau = +inf` "offloading disabled" encoding —
    /// cannot, which is how policy names degrade RT-LM to UP+C.
    pub fn can_claim(&self) -> bool {
        match *self {
            Admission::Fallback | Admission::Nothing => false,
            Admission::Above(x) => x < f64::INFINITY,
            Admission::AtMost(x) => x > f64::NEG_INFINITY,
            Admission::Band(lo, hi) => lo < hi,
        }
    }

    /// Parse the CLI grammar: `default` | `none` | `above:X` |
    /// `atmost:X` | `band:LO:HI`, thresholds resolved by `resolve`
    /// (plain numbers, plus context-dependent tokens like `tau` or
    /// `q0.9` when the caller provides them).
    pub fn parse(s: &str, resolve: &mut dyn FnMut(&str) -> Result<f64>) -> Result<Admission> {
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or("");
        let adm = match head {
            "default" | "fallback" => Admission::Fallback,
            "none" | "nothing" => Admission::Nothing,
            "above" => {
                let x = parts.next().ok_or_else(|| anyhow!("above needs a threshold"))?;
                Admission::Above(resolve(x)?)
            }
            "atmost" => {
                let x = parts.next().ok_or_else(|| anyhow!("atmost needs a threshold"))?;
                Admission::AtMost(resolve(x)?)
            }
            "band" => {
                let lo = parts.next().ok_or_else(|| anyhow!("band needs lo:hi"))?;
                let hi = parts.next().ok_or_else(|| anyhow!("band needs lo:hi"))?;
                Admission::Band(resolve(lo)?, resolve(hi)?)
            }
            other => bail!("unknown admission '{other}' (default | none | above:X | atmost:X | band:LO:HI)"),
        };
        if parts.next().is_some() {
            bail!("trailing tokens in admission spec '{s}'");
        }
        Ok(adm)
    }

    /// Serialise back to the CLI grammar [`Admission::parse`] accepts
    /// (numeric thresholds; `inf` round-trips). Nodes gossip their lane
    /// tables in this form so the router can rebuild the predicates.
    pub fn spec(&self) -> String {
        match *self {
            Admission::Fallback => "default".into(),
            Admission::Nothing => "none".into(),
            Admission::Above(x) => format!("above:{x}"),
            Admission::AtMost(x) => format!("atmost:{x}"),
            Admission::Band(lo, hi) => format!("band:{lo}:{hi}"),
        }
    }
}

/// One execution lane of the fleet.
#[derive(Clone, Debug)]
pub struct LaneSpec {
    /// Display name, unique within the set ("gpu", "cpu", "gpt2-small"…).
    pub name: String,
    /// Device kind: how this lane executes a batch.
    pub kind: LaneKind,
    /// Model variant served by this lane (a `manifest.json` model name;
    /// backends that execute resolve it, pure-logic paths ignore it).
    pub model: String,
    /// Per-lane batch size; `None` uses `SchedParams::batch_size`.
    pub batch_size: Option<usize>,
    /// Intra-batch workers for [`LaneKind::Cpu`] lanes; `None` uses the
    /// device profile's `cpu_workers`.
    pub workers: Option<usize>,
    /// Which tasks this lane claims (see [`Admission`]).
    pub admission: Admission,
    /// Per-lane batching window override (seconds); `None` uses
    /// `SchedParams::xi`. Remote nodes hosting slow variants can carry
    /// a wider window than the fleet default.
    pub xi: Option<f64>,
    /// Per-lane consolidation split override; `None` uses
    /// `SchedParams::lambda`.
    pub lambda: Option<f64>,
    /// For [`LaneKind::Remote`] lanes: the name of the node hosting
    /// this lane. `None` for in-process lanes.
    pub node: Option<String>,
}

impl LaneSpec {
    /// An accelerator fallback lane.
    pub fn accelerator(name: &str, model: &str) -> LaneSpec {
        LaneSpec {
            name: name.into(),
            kind: LaneKind::Accelerator,
            model: model.into(),
            batch_size: None,
            workers: None,
            admission: Admission::Fallback,
            xi: None,
            lambda: None,
            node: None,
        }
    }

    /// A CPU quarantine lane admitting `u > tau`.
    pub fn cpu_offload(name: &str, model: &str, tau: f64) -> LaneSpec {
        LaneSpec {
            name: name.into(),
            kind: LaneKind::Cpu,
            model: model.into(),
            batch_size: None,
            workers: None,
            admission: Admission::Above(tau),
            xi: None,
            lambda: None,
            node: None,
        }
    }
}

/// An ordered, validated table of lanes. The order is the engine's
/// dispatch order (lane 0 is offered a batch first each round) and the
/// routing order (first claiming lane wins).
#[derive(Clone, Debug)]
pub struct LaneSet {
    lanes: Vec<LaneSpec>,
    /// Index of the first fallback lane (validated to exist).
    primary: usize,
}

impl LaneSet {
    /// Validate and seal a lane table: at least one lane, at least one
    /// fallback lane, unique non-empty names, nonzero batch sizes and
    /// worker counts.
    pub fn new(lanes: Vec<LaneSpec>) -> Result<LaneSet> {
        if lanes.is_empty() {
            bail!("a lane set needs at least one lane");
        }
        let primary = lanes
            .iter()
            .position(|l| l.admission == Admission::Fallback)
            .ok_or_else(|| anyhow!("a lane set needs at least one fallback (admit=default) lane"))?;
        for (i, lane) in lanes.iter().enumerate() {
            if lane.name.is_empty() {
                bail!("lane {i} has an empty name");
            }
            if lanes[..i].iter().any(|l| l.name == lane.name) {
                bail!("duplicate lane name '{}'", lane.name);
            }
            if let Some(0) = lane.batch_size {
                bail!("lane '{}' has batch size 0", lane.name);
            }
            if let Some(0) = lane.workers {
                bail!("lane '{}' has 0 workers", lane.name);
            }
            if let Some(x) = lane.xi {
                if !(x.is_finite() && x >= 0.0) {
                    bail!("lane '{}' has invalid xi override {x}", lane.name);
                }
            }
            if let Some(l) = lane.lambda {
                if !(l.is_finite() && l > 0.0) {
                    bail!("lane '{}' has invalid lambda override {l}", lane.name);
                }
            }
        }
        Ok(LaneSet { lanes, primary })
    }

    /// The historical configuration: accelerator fallback lane `gpu` +
    /// CPU quarantine lane `cpu` admitting `u > tau`. Reproduces the
    /// pre-lane-table engine exactly (`tau = +inf` disables offloading).
    pub fn two_lane(model: &str, tau: f64) -> LaneSet {
        LaneSet::new(vec![
            LaneSpec::accelerator("gpu", model),
            LaneSpec::cpu_offload("cpu", model, tau),
        ])
        .expect("two-lane default is valid")
    }

    /// Degenerate single-lane fleet: one accelerator fallback lane.
    pub fn single(model: &str) -> LaneSet {
        LaneSet::new(vec![LaneSpec::accelerator("gpu", model)]).expect("single lane is valid")
    }

    /// Number of lanes in the fleet.
    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    /// Always false (validated non-empty); present for clippy's
    /// len-without-is-empty convention.
    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty() // always false: validated non-empty
    }

    /// Iterate the lane specs in [`LaneId`] order.
    pub fn iter(&self) -> std::slice::Iter<'_, LaneSpec> {
        self.lanes.iter()
    }

    /// Iterate the lane ids `0..len`.
    pub fn ids(&self) -> impl Iterator<Item = LaneId> {
        (0..self.lanes.len()).map(LaneId)
    }

    /// The spec of one lane.
    pub fn spec(&self, id: LaneId) -> &LaneSpec {
        &self.lanes[id.0]
    }

    /// Lane display names, in [`LaneId`] order.
    pub fn names(&self) -> Vec<String> {
        self.lanes.iter().map(|l| l.name.clone()).collect()
    }

    /// The first fallback lane — where unclaimed tasks are routed and
    /// where single-queue baseline policies dispatch.
    pub fn primary(&self) -> LaneId {
        LaneId(self.primary)
    }

    /// Route one task by uncertainty: the first non-fallback lane whose
    /// predicate claims it, else the primary fallback lane.
    pub fn route(&self, u: f64) -> LaneId {
        for (i, lane) in self.lanes.iter().enumerate() {
            if lane.admission.claims(u) {
                return LaneId(i);
            }
        }
        LaneId(self.primary)
    }

    /// Any lane that could pull traffic away from the fallback lane —
    /// i.e. offloading is actually in effect.
    pub fn has_offload(&self) -> bool {
        self.lanes.iter().any(|l| l.admission.can_claim())
    }

    /// Permanently remove a lane from routing (its process died or its
    /// node was evicted): the lane's admission becomes
    /// [`Admission::Nothing`] so it never claims again. If the retired
    /// lane was the primary fallback, the next fallback lane is
    /// promoted; if no fallback lane survives, the first live lane is
    /// *converted* to a fallback so routing stays total. Errors only
    /// when every lane is gone — the fleet can no longer serve.
    pub fn retire(&mut self, id: LaneId) -> Result<()> {
        self.lanes[id.0].admission = Admission::Nothing;
        if id.0 != self.primary {
            return Ok(());
        }
        if let Some(next) = self
            .lanes
            .iter()
            .position(|l| l.admission == Admission::Fallback)
        {
            self.primary = next;
            return Ok(());
        }
        match self
            .lanes
            .iter()
            .position(|l| l.admission != Admission::Nothing)
        {
            Some(live) => {
                self.lanes[live].admission = Admission::Fallback;
                self.primary = live;
                Ok(())
            }
            None => bail!("every lane has been retired; no live lane remains"),
        }
    }

    /// `name=count` pairs in lane order, e.g. `gpu=12 cpu=3` — the
    /// per-lane batch table every report prints.
    pub fn format_counts(&self, counts: &[usize]) -> String {
        format_lane_counts(&self.names(), counts)
    }

    /// Parse the CLI grammar:
    /// `kind[:model][:key=value]*` lanes joined by commas, e.g.
    /// `gpu:gpt2-large,cpu:gpt2-medium:workers=4`. Keys: `name=`,
    /// `workers=N`, `batch=N`, `admit=SPEC` (see [`Admission::parse`]).
    /// Defaults: model = `default_model`; admission = `default` for the
    /// first `gpu` lane, `above:tau` for `cpu` lanes (resolved by
    /// `resolve`), `default` otherwise; name = kind, suffixed with the
    /// lane index on collision.
    pub fn parse(
        spec: &str,
        default_model: &str,
        resolve: &mut dyn FnMut(&str) -> Result<f64>,
    ) -> Result<LaneSet> {
        let mut lanes: Vec<LaneSpec> = Vec::new();
        for (idx, lane_str) in spec.split(',').enumerate() {
            let lane_str = lane_str.trim();
            if lane_str.is_empty() {
                bail!("empty lane in --lanes spec");
            }
            let mut parts = lane_str.split(':');
            let kind_str = parts.next().unwrap();
            let kind = LaneKind::parse(kind_str)?;
            let mut model = default_model.to_string();
            let mut name: Option<String> = None;
            let mut workers = None;
            let mut batch_size = None;
            let mut xi = None;
            let mut lambda = None;
            let mut admission: Option<Admission> = None;
            let mut first = true;
            let mut rest = parts;
            while let Some(tok) = rest.next() {
                if let Some((key, value)) = tok.split_once('=') {
                    match key {
                        "name" => name = Some(value.to_string()),
                        "workers" => {
                            workers = Some(value.parse().map_err(|_| {
                                anyhow!("bad workers '{value}' in lane '{lane_str}'")
                            })?)
                        }
                        "batch" => {
                            batch_size = Some(value.parse().map_err(|_| {
                                anyhow!("bad batch '{value}' in lane '{lane_str}'")
                            })?)
                        }
                        "xi" => {
                            xi = Some(value.parse().map_err(|_| {
                                anyhow!("bad xi '{value}' in lane '{lane_str}'")
                            })?)
                        }
                        "lambda" => {
                            lambda = Some(value.parse().map_err(|_| {
                                anyhow!("bad lambda '{value}' in lane '{lane_str}'")
                            })?)
                        }
                        "admit" => {
                            // band:LO:HI spills into the next ':' tokens
                            let mut full = value.to_string();
                            let extra = match value {
                                "above" | "atmost" => 1,
                                "band" => 2,
                                _ => 0,
                            };
                            for _ in 0..extra {
                                let t = rest.next().ok_or_else(|| {
                                    anyhow!("truncated admit spec in lane '{lane_str}'")
                                })?;
                                full.push(':');
                                full.push_str(t);
                            }
                            admission = Some(Admission::parse(&full, resolve)?);
                        }
                        other => bail!("unknown lane option '{other}' in '{lane_str}'"),
                    }
                } else if first {
                    // the first bare token is the model variant
                    model = tok.to_string();
                } else {
                    bail!("unexpected token '{tok}' in lane '{lane_str}' (options are key=value)");
                }
                first = false;
            }
            let admission = match admission {
                Some(a) => a,
                None => match kind {
                    LaneKind::Cpu => Admission::Above(resolve("tau")?),
                    LaneKind::Accelerator | LaneKind::Remote => Admission::Fallback,
                },
            };
            // only *derived* default names auto-suffix on collision; an
            // explicit duplicate `name=` is a config error that
            // LaneSet::new rejects rather than silently renames
            let name = match name {
                Some(explicit) => explicit,
                None => {
                    let base = kind_str.to_string();
                    if lanes.iter().any(|l| l.name == base) {
                        format!("{base}{idx}")
                    } else {
                        base
                    }
                }
            };
            lanes.push(LaneSpec {
                name,
                kind,
                model,
                batch_size,
                workers,
                admission,
                xi,
                lambda,
                node: None,
            });
        }
        LaneSet::new(lanes)
    }

    /// Parse a JSON lane file: an array of objects with keys `kind`
    /// (required), `model`, `name`, `workers`, `batch`, `admit`, `xi`,
    /// `lambda` — the same semantics and defaults as the CLI grammar.
    pub fn parse_json(
        json: &Json,
        default_model: &str,
        resolve: &mut dyn FnMut(&str) -> Result<f64>,
    ) -> Result<LaneSet> {
        let arr = json
            .as_arr()
            .ok_or_else(|| anyhow!("lane file must be a JSON array of lane objects"))?;
        let mut lanes = Vec::new();
        for (idx, entry) in arr.iter().enumerate() {
            let kind_str = entry.need_str("kind")?;
            let kind = LaneKind::parse(kind_str)?;
            let model = entry
                .get("model")
                .as_str()
                .unwrap_or(default_model)
                .to_string();
            let name = entry
                .get("name")
                .as_str()
                .map(str::to_string)
                .unwrap_or_else(|| format!("{kind_str}{idx}"));
            let workers = entry.get("workers").as_usize();
            let batch_size = entry.get("batch").as_usize();
            let xi = entry.get("xi").as_f64();
            let lambda = entry.get("lambda").as_f64();
            let admission = match entry.get("admit").as_str() {
                Some(s) => Admission::parse(s, resolve)?,
                None => match kind {
                    LaneKind::Cpu => Admission::Above(resolve("tau")?),
                    LaneKind::Accelerator | LaneKind::Remote => Admission::Fallback,
                },
            };
            lanes.push(LaneSpec {
                name,
                kind,
                model,
                batch_size,
                workers,
                admission,
                xi,
                lambda,
                node: None,
            });
        }
        LaneSet::new(lanes)
    }
}

impl std::ops::Index<LaneId> for LaneSet {
    type Output = LaneSpec;
    fn index(&self, id: LaneId) -> &LaneSpec {
        &self.lanes[id.0]
    }
}

/// `name=count` pairs for reports that carry lane names without the
/// full [`LaneSet`].
pub fn format_lane_counts(names: &[String], counts: &[usize]) -> String {
    names
        .iter()
        .zip(counts)
        .map(|(n, c)| format!("{n}={c}"))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Threshold resolver over plain numbers only (`inf` allowed) — test
/// and library contexts with no workload statistics in scope.
pub fn numeric_thresholds(tok: &str) -> Result<f64> {
    match tok {
        "inf" => Ok(f64::INFINITY),
        _ => tok
            .parse()
            .map_err(|_| anyhow!("threshold '{tok}' is not a number (tau/quantile tokens need workload scores)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_lane_routes_like_tau() {
        let lanes = LaneSet::two_lane("m", 60.0);
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes.primary(), LaneId::GPU);
        assert_eq!(lanes.route(10.0), LaneId::GPU);
        assert_eq!(lanes.route(60.0), LaneId::GPU); // u > tau strictly
        assert_eq!(lanes.route(60.1), LaneId::CPU);
        assert_eq!(lanes.route(f64::NAN), LaneId::GPU); // unscorable -> fallback
    }

    #[test]
    fn infinite_tau_disables_offload() {
        let lanes = LaneSet::two_lane("m", f64::INFINITY);
        assert!(!lanes.has_offload());
        assert_eq!(lanes.route(1e12), LaneId::GPU);
    }

    #[test]
    fn first_claiming_lane_wins() {
        let lanes = LaneSet::new(vec![
            LaneSpec::accelerator("big", "m1"),
            LaneSpec {
                admission: Admission::AtMost(20.0),
                ..LaneSpec::accelerator("small", "m2")
            },
            LaneSpec::cpu_offload("cpu", "m1", 60.0),
        ])
        .unwrap();
        assert_eq!(lanes.route(10.0), LaneId(1));
        assert_eq!(lanes.route(30.0), LaneId(0));
        assert_eq!(lanes.route(90.0), LaneId(2));
    }

    #[test]
    fn validation_rejects_bad_sets() {
        assert!(LaneSet::new(vec![]).is_err());
        // no fallback lane
        assert!(LaneSet::new(vec![LaneSpec::cpu_offload("cpu", "m", 60.0)]).is_err());
        // duplicate names
        assert!(LaneSet::new(vec![
            LaneSpec::accelerator("gpu", "m"),
            LaneSpec::accelerator("gpu", "m"),
        ])
        .is_err());
    }

    #[test]
    fn parse_cli_grammar() {
        let mut resolve = |tok: &str| match tok {
            "tau" => Ok(55.0),
            _ => numeric_thresholds(tok),
        };
        let lanes = LaneSet::parse(
            "gpu:gpt2-large,gpu:gpt2-medium:admit=atmost:20:batch=8,cpu:gpt2-medium:workers=4",
            "gpt2-large",
            &mut resolve,
        )
        .unwrap();
        assert_eq!(lanes.len(), 3);
        assert_eq!(lanes.spec(LaneId(0)).model, "gpt2-large");
        assert_eq!(lanes.spec(LaneId(0)).admission, Admission::Fallback);
        assert_eq!(lanes.spec(LaneId(1)).admission, Admission::AtMost(20.0));
        assert_eq!(lanes.spec(LaneId(1)).batch_size, Some(8));
        assert_eq!(lanes.spec(LaneId(1)).name, "gpu1"); // deduplicated
        assert_eq!(lanes.spec(LaneId(2)).kind, LaneKind::Cpu);
        assert_eq!(lanes.spec(LaneId(2)).workers, Some(4));
        assert_eq!(lanes.spec(LaneId(2)).admission, Admission::Above(55.0));
        assert_eq!(lanes.route(90.0), LaneId(2));
        assert_eq!(lanes.route(15.0), LaneId(1));
    }

    #[test]
    fn parse_rejects_explicit_duplicate_names() {
        // derived names auto-suffix...
        let ok = LaneSet::parse("gpu,gpu", "m", &mut numeric_thresholds).unwrap();
        assert_eq!(ok.names(), vec!["gpu", "gpu1"]);
        // ...but an explicit duplicate name= is a config error
        let err = LaneSet::parse(
            "gpu:name=fast,gpu:name=fast:admit=atmost:20",
            "m",
            &mut numeric_thresholds,
        );
        assert!(err.is_err(), "explicit duplicate lane name must be rejected");
    }

    #[test]
    fn parse_bare_kind_uses_default_model() {
        let lanes =
            LaneSet::parse("gpu,cpu", "t5", &mut |t| match t {
                "tau" => Ok(60.0),
                _ => numeric_thresholds(t),
            })
            .unwrap();
        assert_eq!(lanes.spec(LaneId(0)).model, "t5");
        assert_eq!(lanes.spec(LaneId(1)).admission, Admission::Above(60.0));
    }

    #[test]
    fn parse_json_lane_file() {
        let json = Json::parse(
            r#"[
            {"kind": "gpu", "model": "big"},
            {"kind": "gpu", "model": "small", "name": "fast", "admit": "band:4:20"},
            {"kind": "cpu", "workers": 2, "admit": "above:60"}
        ]"#,
        )
        .unwrap();
        let lanes = LaneSet::parse_json(&json, "big", &mut numeric_thresholds).unwrap();
        assert_eq!(lanes.len(), 3);
        assert_eq!(lanes.spec(LaneId(1)).name, "fast");
        assert_eq!(lanes.spec(LaneId(1)).admission, Admission::Band(4.0, 20.0));
        assert_eq!(lanes.spec(LaneId(2)).workers, Some(2));
    }

    #[test]
    fn format_counts_matches_report_style() {
        let lanes = LaneSet::two_lane("m", 60.0);
        assert_eq!(lanes.format_counts(&[12, 3]), "gpu=12 cpu=3");
    }

    #[test]
    fn admission_nothing_never_claims() {
        let a = Admission::Nothing;
        for u in [0.0, 50.0, f64::INFINITY, f64::NAN] {
            assert!(!a.claims(u));
        }
        assert!(!a.can_claim());
    }

    #[test]
    fn admission_spec_round_trips_through_parse() {
        let cases = [
            Admission::Fallback,
            Admission::Nothing,
            Admission::Above(60.5),
            Admission::Above(f64::INFINITY),
            Admission::AtMost(20.0),
            Admission::Band(4.0, 20.0),
        ];
        for adm in cases {
            let back = Admission::parse(&adm.spec(), &mut numeric_thresholds).unwrap();
            assert_eq!(back, adm, "spec '{}' must round-trip", adm.spec());
        }
    }

    #[test]
    fn parse_accepts_per_lane_xi_and_lambda_overrides() {
        let lanes = LaneSet::parse(
            "gpu:t5:xi=0.5:lambda=2.0,cpu:t5",
            "t5",
            &mut |t| if t == "tau" { Ok(60.0) } else { numeric_thresholds(t) },
        )
        .unwrap();
        assert_eq!(lanes.spec(LaneId(0)).xi, Some(0.5));
        assert_eq!(lanes.spec(LaneId(0)).lambda, Some(2.0));
        assert_eq!(lanes.spec(LaneId(1)).xi, None);
        assert_eq!(lanes.spec(LaneId(1)).lambda, None);

        // json lane files carry the same keys
        let json = Json::parse(r#"[{"kind": "gpu", "xi": 0.25, "lambda": 1.2}]"#).unwrap();
        let lanes = LaneSet::parse_json(&json, "m", &mut numeric_thresholds).unwrap();
        assert_eq!(lanes.spec(LaneId(0)).xi, Some(0.25));
        assert_eq!(lanes.spec(LaneId(0)).lambda, Some(1.2));

        // invalid overrides are rejected at validation time
        assert!(LaneSet::parse("gpu:xi=-1", "m", &mut numeric_thresholds).is_err());
        assert!(LaneSet::parse("gpu:lambda=0", "m", &mut numeric_thresholds).is_err());
    }

    #[test]
    fn retire_removes_lane_and_keeps_routing_total() {
        let mut lanes = LaneSet::new(vec![
            LaneSpec::accelerator("a/gpu", "m"),
            LaneSpec::accelerator("b/gpu", "m"),
            LaneSpec::cpu_offload("b/cpu", "m", 60.0),
        ])
        .unwrap();
        assert_eq!(lanes.primary(), LaneId(0));

        // primary dies -> next fallback is promoted
        lanes.retire(LaneId(0)).unwrap();
        assert_eq!(lanes.primary(), LaneId(1));
        assert_eq!(lanes.route(10.0), LaneId(1));
        assert_eq!(lanes.route(90.0), LaneId(2), "claiming lanes keep claiming");

        // last fallback dies -> a claiming lane is converted to fallback
        lanes.retire(LaneId(1)).unwrap();
        assert_eq!(lanes.primary(), LaneId(2));
        assert_eq!(lanes.route(10.0), LaneId(2));

        // the whole fleet is gone
        assert!(lanes.retire(LaneId(2)).is_err());
    }
}
