//! Dynamic consolidation (Sec. IV-C): batch tasks with *similar*
//! uncertainty so no single long task holds the whole batch hostage.
//!
//! Pure segmentation logic, shared by [`super::uasched::UaSched`] and the
//! Fig. 5 illustration harness.

use super::task::Task;

/// Given tasks sorted by ascending uncertainty, return how many to
//  execute as one batch: walk the list and stop at the first task whose
/// uncertainty exceeds `lambda` times the previous one's, or when the
/// batch size `c` is reached (Algorithm 1, lines 20-25).
pub fn split_point(sorted: &[Task], lambda: f64, c: usize) -> usize {
    if sorted.is_empty() {
        return 0;
    }
    let c = c.max(1);
    let mut count = 1;
    let mut u_prev = sorted[0].uncertainty;
    while count < sorted.len() && count < c {
        let u = sorted[count].uncertainty;
        if u > lambda * u_prev.max(1e-9) {
            break;
        }
        u_prev = u;
        count += 1;
    }
    count
}

/// Sort tasks by ascending uncertainty (stable; ties keep queue order).
/// `total_cmp` keeps the order total — a NaN uncertainty sorts last
/// instead of panicking the scheduler.
pub fn sort_by_uncertainty(tasks: &mut [Task]) {
    tasks.sort_by(|a, b| a.uncertainty.total_cmp(&b.uncertainty));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::task::test_task;
    use crate::util::prop;

    fn tasks_with_u(us: &[f64]) -> Vec<Task> {
        us.iter()
            .enumerate()
            .map(|(i, &u)| test_task(i as u64, 0.0, 10.0, u))
            .collect()
    }

    #[test]
    fn splits_at_ratio_violation() {
        let t = tasks_with_u(&[10.0, 12.0, 14.0, 40.0, 45.0]);
        // 40 > 1.5 * 14 -> split after 3
        assert_eq!(split_point(&t, 1.5, 8), 3);
    }

    #[test]
    fn respects_batch_size_cap() {
        let t = tasks_with_u(&[10.0, 10.0, 10.0, 10.0, 10.0]);
        assert_eq!(split_point(&t, 1.5, 3), 3);
    }

    #[test]
    fn single_task_batches_alone() {
        let t = tasks_with_u(&[99.0]);
        assert_eq!(split_point(&t, 1.5, 4), 1);
    }

    #[test]
    fn first_task_always_included_even_if_huge() {
        let t = tasks_with_u(&[1000.0, 1001.0]);
        assert_eq!(split_point(&t, 1.5, 4), 2); // 1001 <= 1.5*1000
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(split_point(&[], 1.5, 4), 0);
    }

    #[test]
    fn prop_split_in_bounds_and_ratio_holds() {
        prop::check_result(
            "split-point-invariants",
            300,
            |rng| {
                let n = rng.range_usize(1, 20);
                let us: Vec<f64> = (0..n).map(|_| rng.f64() * 90.0 + 4.0).collect();
                let lambda = 1.0 + rng.f64() * 2.0;
                let c = rng.range_usize(1, 12);
                (us, lambda, c)
            },
            |(us, lambda, c)| {
                let mut tasks = tasks_with_u(us);
                sort_by_uncertainty(&mut tasks);
                let split = split_point(&tasks, *lambda, *c);
                if split == 0 || split > tasks.len() || split > *c {
                    return Err(format!("split {split} out of bounds"));
                }
                // every adjacent pair inside the batch respects lambda
                for w in tasks[..split].windows(2) {
                    if w[1].uncertainty > lambda * w[0].uncertainty.max(1e-9) + 1e-12 {
                        return Err(format!(
                            "ratio violated inside batch: {} > {lambda} * {}",
                            w[1].uncertainty, w[0].uncertainty
                        ));
                    }
                }
                // maximality: if we stopped early (not at c, not at end),
                // the next task must violate the ratio
                if split < *c && split < tasks.len() {
                    let u_prev = tasks[split - 1].uncertainty;
                    if tasks[split].uncertainty <= lambda * u_prev.max(1e-9) {
                        return Err("stopped early without a violation".into());
                    }
                }
                Ok(())
            },
        );
    }
}
