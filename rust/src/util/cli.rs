//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw argument strings (without argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(arg);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected a number, got '{s}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected an integer, got '{s}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected an integer, got '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixes_styles() {
        let a = parse(&["sim", "--alpha", "1.5", "--quiet", "--b=2.0", "trailing"]);
        assert_eq!(a.positional, vec!["sim", "trailing"]);
        assert_eq!(a.get("alpha"), Some("1.5"));
        assert_eq!(a.get("b"), Some("2.0"));
        assert!(a.flag("quiet"));
        assert!(!a.flag("alpha"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--x", "2.5", "--n", "7"]);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("n", 0).unwrap(), 7);
        assert_eq!(a.get_f64("missing", 9.0).unwrap(), 9.0);
        assert!(parse(&["--x", "abc"]).get_f64("x", 0.0).is_err());
    }
}
