//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Arguments carrying no `--` prefix, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw argument strings (without argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(arg);
            }
        }
        args
    }

    /// Parse the process arguments (argv[0] skipped).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Was the bare switch `--name` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` parsed as a float (error message names the flag).
    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected a number, got '{s}'")),
        }
    }

    /// `--name` parsed as an unsigned integer.
    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected an integer, got '{s}'")),
        }
    }

    /// `--name` parsed as a u64 (seeds).
    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected an integer, got '{s}'")),
        }
    }
}

/// The `rtlm` top-level usage text, parameterised over the experiment
/// list so `bench`'s completions stay in sync with
/// `bench_harness::scenarios::EXPERIMENTS`.
///
/// Lives in the library (not `main.rs`) so `rust/tests/unit_smoke.rs`
/// can assert that every public flag of every subcommand is mentioned —
/// the regression gate for help-text drift.
pub fn help_text(experiments: &[&str]) -> String {
    format!(
        "rtlm — uncertainty-aware resource management for real-time LM serving\n\n\
         usage: rtlm <command> [--artifacts DIR] [options]\n\n\
         commands:\n\
         \x20 check                      validate artifacts, smoke inference\n\
         \x20 calibrate [--reps N]       measure PJRT latencies -> calib.json\n\
         \x20 bench <exp|all> [--n N] [--seed S] [--sched batch|step]\n\
         \x20     [--queue-cap N] [--shed priority|length]\n\
         \x20     regenerate paper experiments: {exps}\n\
         \x20 bench --wire [FILTER] [--n N] [--seed S] [--time-scale S]\n\
         \x20     [--parity-rel R] [--parity-slop-ms MS] [--parity-out FILE]\n\
         \x20     replay the internal comparison cells through both the\n\
         \x20     virtual-clock simulator and the threaded wire engine and\n\
         \x20     diff the reports (per-lane batch counts exact, response\n\
         \x20     stats within a time-scale-aware tolerance); nonzero exit\n\
         \x20     on any parity failure. FILTER keeps cells whose label\n\
         \x20     contains it (also accepted as --wire FILTER).\n\
         \x20 gauntlet [--n N] [--seed S] [--policies p1,p2] [--scenarios s1,s2]\n\
         \x20     [--wire SCENARIOS] [--time-scale S] [--out FILE]\n\
         \x20     run the policy x scenario matrix (artifact-free: synthetic\n\
         \x20     seeded traces — nominal, diurnal, flash, heavytail,\n\
         \x20     edge-cpu — with a 50/50 interactive/batch SLO mix) on the\n\
         \x20     virtual clock, wire-replaying the --wire subset (comma\n\
         \x20     list or 'all'), print the per-cell attainment table, and\n\
         \x20     write the deterministic JSON report to --out; nonzero\n\
         \x20     exit on any cell error or wire parity failure.\n\
         \x20 sim [--model M] [--policy P] [--n N] [--seed S] [--device D]\n\
         \x20     [--variance small|normal|large] [--sched batch|step]\n\
         \x20     [--slots N] [--overrun-factor F] [--queue-cap N]\n\
         \x20     [--shed priority|length] [--export FILE]\n\
         \x20 serve [--model M] [--policy P] [--n N] [--seed S] [--beta B]\n\
         \x20     [--time-scale S] [--backend pjrt|modeled] [--device D]\n\
         \x20     [--variance V] [--lanes SPEC] [--sched batch|step] [--slots N]\n\
         \x20     [--overrun-factor F] [--queue-cap N] [--shed priority|length]\n\
         \x20     [--require-all-lanes] [--verbose]\n\
         \x20 tcp [--model M] [--addr A] [--policy P] [--backend pjrt|modeled]\n\
         \x20     [--time-scale S] [--device D] [--lanes SPEC] [--pipeline K]\n\
         \x20     [--sched batch|step] [--slots N] [--overrun-factor F]\n\
         \x20     [--queue-cap N] [--shed priority|length]\n\
         \x20     [--node-name NAME] [--register ADDR]\n\
         \x20 route [--addr A] [--policy P] [--nodes a:p,b:p] [--expect-nodes N]\n\
         \x20     [--heartbeat-s S] [--pipeline K] [--sched batch|step]\n\
         \x20     [--queue-cap N] [--shed priority|length]\n\
         \x20     distributed-fleet router: unions the lane tables of every\n\
         \x20     node (dialed via --nodes, or registering via their\n\
         \x20     --register flag) into one node/lane fleet, scores\n\
         \x20     uncertainty once at admission, and proxies batches to the\n\
         \x20     owning node over framed TCP. Nodes missing 2 heartbeats\n\
         \x20     are evicted and their in-flight tasks re-queue through\n\
         \x20     ordinary lane admission on the survivors.\n\
         \x20 loadgen [--addr A] [--n N] [--concurrency K] [--p95-ms MS]\n\
         \x20     [--timeout-s S] [--connect-wait-s S] [--expect-lanes a,b]\n\
         \x20     [--allow-server-errors] [--rate R] [--min-shed N]\n\
         \x20     [--max-shed-rate F]\n\
         \x20     --rate R fires requests open-loop at R req/s Poisson\n\
         \x20     arrivals (0 = closed loop); shed replies are tallied\n\
         \x20     separately and gated by --min-shed / --max-shed-rate.\n\
         \x20 score <text...>            print RULEGEN features + u_J\n\n\
         --lanes describes the fleet: comma-separated kind[:model][:key=value]*\n\
         (keys: name, workers, batch, admit=default|none|above:X|atmost:X|band:L:H,\n\
         xi=S, lambda=L — per-lane overrides of the batch-wait interval and\n\
         the consolidation split; thresholds take numbers, inf, tau, or qP\n\
         quantiles), or @lanes.json.\n\
         e.g. --lanes \"gpu:t5,gpu:godel:admit=atmost:q0.3,cpu:t5:workers=4\"\n\n\
         --sched step turns on iteration-level (continuous) batching:\n\
         accelerator lanes run a persistent decode loop over --slots slots\n\
         (0 = lane batch size); generations exceeding --overrun-factor x\n\
         their predicted length are preempted to the CPU lane.\n\n\
         --queue-cap N bounds every lane's waiting queue (0 = unbounded):\n\
         a push into a full lane sheds one task per --shed — priority\n\
         drops the lowest-priority task under the lane's own order,\n\
         length the highest-predicted-length one. Shed requests answer\n\
         immediately with an id-tagged {{\"error\":\"shed\"}} reply.",
        exps = experiments.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixes_styles() {
        let a = parse(&["sim", "--alpha", "1.5", "--quiet", "--b=2.0", "trailing"]);
        assert_eq!(a.positional, vec!["sim", "trailing"]);
        assert_eq!(a.get("alpha"), Some("1.5"));
        assert_eq!(a.get("b"), Some("2.0"));
        assert!(a.flag("quiet"));
        assert!(!a.flag("alpha"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--x", "2.5", "--n", "7"]);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("n", 0).unwrap(), 7);
        assert_eq!(a.get_f64("missing", 9.0).unwrap(), 9.0);
        assert!(parse(&["--x", "abc"]).get_f64("x", 0.0).is_err());
    }
}
