//! Foundation utilities built in-tree because the offline crate set has
//! no serde/clap/rand/proptest: a minimal JSON value model, a PCG64 RNG,
//! a CLI argument parser, and a tiny property-testing harness.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

use std::time::Instant;

/// Measure a closure's wall time in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}
