//! Deterministic PCG64 (XSL-RR) random number generator.
//!
//! The `rand` crate is not in the offline set; this is the reference
//! PCG64 algorithm with helpers for the distributions the workload
//! engine needs (uniform, normal, exponential, weighted choice).

/// PCG64 XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed a generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seed a generator on an explicit stream (distinct streams
    /// diverge even under the same seed).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent generator (stable function of `salt`).
    pub fn fork(&mut self, salt: u64) -> Pcg64 {
        Pcg64::with_stream(self.next_u64() ^ salt, salt.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        // Lemire-style rejection-free-enough for non-crypto use
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal draw with the given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Exponential with the given mean (inter-arrival sampling).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).max(1e-300).ln()
    }

    /// Uniform choice from a non-empty slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range_usize(0, items.len())]
    }

    /// Weighted index choice; weights need not be normalised.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range_usize(0, i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Pcg64::new(3);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(9);
        let n = 50_000;
        let mean = (0..n).map(|_| rng.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg64::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.weighted_index(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0], "{counts:?}");
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.05, "{frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_bounds() {
        let mut rng = Pcg64::new(13);
        for _ in 0..1000 {
            let x = rng.range_u64(5, 10);
            assert!((5..10).contains(&x));
        }
    }
}
