//! Minimal JSON parser/writer (serde is not in the offline crate set).
//!
//! Covers exactly what the artifact contract needs: UTF-8 text, `f64`
//! numbers, escaped strings (incl. `\uXXXX`), arrays, objects with
//! insertion-ordered keys. Errors carry byte offsets for debuggability.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always held as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure, with the byte offset it occurred at.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset into the input text.
    pub offset: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing characters rejected).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number value truncated to `i64`.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    /// The number value truncated to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Json::Null` if out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- convenience "must" accessors (anyhow-friendly) ----------------------

    /// Required numeric field of an object (error names the key).
    pub fn need_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing/not-a-number field '{key}'"))
    }

    /// Required string field of an object.
    pub fn need_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/not-a-string field '{key}'"))
    }

    /// Required array field of an object.
    pub fn need_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing/not-an-array field '{key}'"))
    }

    /// Required object field of an object.
    pub fn need_obj(&self, key: &str) -> anyhow::Result<&BTreeMap<String, Json>> {
        self.get(key)
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("missing/not-an-object field '{key}'"))
    }
}

// -- writer ------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Build an object from pairs (test/report helper).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// -- parser ------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let combined =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(combined)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 codepoint
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Read a JSONL file into parsed values (one per non-empty line).
pub fn read_jsonl(path: &std::path::Path) -> anyhow::Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(
            Json::parse(line)
                .map_err(|e| anyhow::anyhow!("{}:{}: {e}", path.display(), i + 1))?,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"a":[1,2.5,true,null,"s"],"b":{"c":-1}}"#,
            r#"[]"#,
            r#"{"x":"quote\" and \\ backslash"}"#,
        ];
        for case in cases {
            let v = Json::parse(case).unwrap();
            let text = v.to_string();
            assert_eq!(Json::parse(&text).unwrap(), v, "{case}");
        }
    }

    #[test]
    fn missing_field_is_null() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.get("nope").get("deeper"), &Json::Null);
        assert!(v.need_f64("nope").is_err());
    }
}
