//! Miniature property-testing harness (proptest is not in the offline
//! crate set).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs from independent seeds; on failure it reports the seed so the
//! case can be replayed deterministically. No shrinking — generators
//! should keep inputs small instead.

use super::rng::Pcg64;

/// Run `prop` on `cases` random inputs. Panics (with the failing seed)
/// on the first falsified case.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    mut generate: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for seed in 0..cases {
        let mut rng = Pcg64::with_stream(0xC0FFEE ^ seed, seed);
        let input = generate(&mut rng);
        if !prop(&input) {
            panic!("property '{name}' falsified at seed {seed} with input: {input:#?}");
        }
    }
}

/// Like [`check`] but the property returns `Result`, so failures can
/// carry a message.
pub fn check_result<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    mut generate: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for seed in 0..cases {
        let mut rng = Pcg64::with_stream(0xC0FFEE ^ seed, seed);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property '{name}' falsified at seed {seed}: {msg}\ninput: {input:#?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check("reverse-twice", 50, |rng| {
            let n = rng.range_usize(0, 20);
            (0..n).map(|_| rng.next_u64()).collect::<Vec<_>>()
        }, |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn fails_false_property() {
        check("always-false", 5, |rng| rng.next_u64(), |_| false);
    }
}
