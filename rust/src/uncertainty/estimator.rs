//! The combined uncertainty estimator used on the scheduling hot path:
//! RULEGEN features -> LW regressor -> uncertainty score (predicted
//! output length in tokens). Eq. 1: u_J = m_theta(RULEGEN(J)).

use std::sync::Arc;

use anyhow::Result;

use super::fastpath;
use super::regressor::Regressor;
use super::rules;
use crate::textgen::{Lexicon, ScoreScratch};

/// The combined RULEGEN + LW-regressor estimator (Eq. 1).
#[derive(Clone)]
pub struct Estimator {
    lexicon: Arc<Lexicon>,
    regressor: Arc<Regressor>,
    max_input_len: usize,
    min_len: f64,
    max_len: f64,
}

impl Estimator {
    /// Assemble the estimator. `min_len`/`max_len` bound the score (the
    /// manifest's output-length range); `max_input_len` truncates
    /// feature extraction.
    pub fn new(
        lexicon: Arc<Lexicon>,
        regressor: Arc<Regressor>,
        max_input_len: usize,
        min_len: f64,
        max_len: f64,
    ) -> Estimator {
        Estimator { lexicon, regressor, max_input_len, min_len, max_len }
    }

    /// Clamp a raw regressor output to a *finite* score in the model
    /// family's valid range. `f64::clamp` propagates NaN, so a broken
    /// regressor would otherwise leak NaN into the scheduler's priority
    /// queue; an unscorable utterance is treated as maximally uncertain
    /// (the conservative choice — it lands in the quarantine lane, not
    /// at the front of the accelerator queue).
    fn clamp_score(&self, raw: f64) -> f64 {
        if raw.is_finite() {
            raw.clamp(self.min_len, self.max_len)
        } else {
            self.max_len
        }
    }

    /// The RULEGEN feature vector of a text.
    pub fn features(&self, text: &str) -> [f64; rules::N_FEATURES] {
        rules::features(&self.lexicon, text, self.max_input_len)
    }

    /// [`Self::features`] via the single-pass interned fast path —
    /// bit-identical output, allocation-free at steady state when the
    /// same scratch is reused across calls.
    pub fn features_scratch(
        &self,
        text: &str,
        scratch: &mut ScoreScratch,
    ) -> [f64; rules::N_FEATURES] {
        fastpath::features_scratch(&self.lexicon, text, self.max_input_len, scratch)
    }

    /// Uncertainty score for a text: predicted output length, clamped to
    /// the model family's valid range.
    pub fn score(&self, text: &str) -> Result<f64> {
        let feats = self.features(text);
        let raw = self.regressor.predict(&feats)?;
        Ok(self.clamp_score(raw))
    }

    /// Score a pre-computed raw feature vector (simulation fast path —
    /// skips tokenisation when build-time features are available).
    pub fn score_features(&self, raw_features: &[f64]) -> Result<f64> {
        let raw = self.regressor.predict(raw_features)?;
        Ok(self.clamp_score(raw))
    }

    /// Score plus the feature vector (the scheduler logs both).
    pub fn score_with_features(&self, text: &str) -> Result<(f64, [f64; rules::N_FEATURES])> {
        let feats = self.features(text);
        let raw = self.regressor.predict(&feats)?;
        Ok((self.clamp_score(raw), feats))
    }

    /// [`Self::score`] via the fast path (bit-identical score, no
    /// steady-state allocations with a reused scratch).
    pub fn score_scratch(&self, text: &str, scratch: &mut ScoreScratch) -> Result<f64> {
        Ok(self.score_with_features_scratch(text, scratch)?.0)
    }

    /// [`Self::score_with_features`] via the fast path: single-pass
    /// interned feature extraction plus the regressor's ping-pong
    /// buffers, all living in the caller's [`ScoreScratch`].
    pub fn score_with_features_scratch(
        &self,
        text: &str,
        scratch: &mut ScoreScratch,
    ) -> Result<(f64, [f64; rules::N_FEATURES])> {
        let feats = fastpath::features_scratch(&self.lexicon, text, self.max_input_len, scratch);
        let raw = self
            .regressor
            .predict_into(&feats, &mut scratch.reg_a, &mut scratch.reg_b)?;
        Ok((self.clamp_score(raw), feats))
    }

    /// The paper's weighted-rule baseline (Fig. 2c): linear model over
    /// the feature vector.
    pub fn weighted_rule(&self, text: &str, coef: &[f64], intercept: f64) -> f64 {
        let feats = self.features(text);
        feats.iter().zip(coef).map(|(f, c)| f * c).sum::<f64>() + intercept
    }

    /// The lexicon feature extraction runs against.
    pub fn lexicon(&self) -> &Lexicon {
        &self.lexicon
    }
}
