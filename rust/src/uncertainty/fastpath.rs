//! Single-pass, zero-steady-state-allocation RULEGEN scoring.
//!
//! [`features_scratch`] produces the exact feature vector of
//! [`super::rules::features`] — bit-identical f64s, asserted by the
//! golden and property suites — while doing one interned-table lookup
//! per token instead of ~10 `String`-keyed set probes, tagging from the
//! same lookup, and writing only into reusable [`ScoreScratch`]
//! buffers (no per-call `Vec<String>` tokens, no per-token `String`s,
//! no transient phrase vectors).
//!
//! Bit-equality argument: every rule score is a sum/product of small
//! exact integers (counts times 2.0/3.0/4.0/5.0), each exactly
//! representable in f64, so the results are exact and association
//! cannot change them; the accumulation order below still mirrors the
//! legacy scorers line for line so the equivalence holds trivially,
//! not just analytically. The one behavioural difference is where the
//! facts come from — the compiled [`crate::textgen::ScoreTable`],
//! which holds exactly the same word lists.

use crate::textgen::intern::{
    FLAG_AND, FLAG_HOMONYM, FLAG_MULTIPART, FLAG_NV_AMBIG, FLAG_OF, FLAG_OPEN_MARKER,
    FLAG_OPEN_WH, FLAG_POS, FLAG_RELATIVIZER, FLAG_VAGUE_ADJ, FLAG_VAGUE_TOPIC, FLAG_WH, NO_WORD,
};
use crate::textgen::lexicon::{Lexicon, Tag};
use crate::textgen::tokenizer::{is_punct_byte, tokenize_into, ScoreScratch};

use super::rules::N_FEATURES;

/// Does the interned token-id sequence contain `phrase` as a
/// contiguous run? Mirror of the legacy `contains_phrase` (including
/// its `false` for empty phrases), over word ids instead of `String`s.
/// Unknown tokens carry [`NO_WORD`], which never equals an interned
/// phrase-word id, so they can only ever fail a match — same as an
/// unknown `String` token.
#[inline]
fn contains_phrase_ids(ids: &[u32], phrase: &[u32]) -> bool {
    if phrase.is_empty() || ids.len() < phrase.len() {
        return false;
    }
    ids.windows(phrase.len()).any(|w| w == phrase)
}

/// The full RULEGEN feature vector (six rule scores + clamped input
/// length), computed in a single pass over the tokens with one
/// [`crate::textgen::ScoreTable`] lookup per token. Bit-identical to
/// [`super::rules::features`]; allocation-free at steady state (the
/// scratch buffers grow to capacity over the first few calls, then
/// every subsequent call reuses them).
pub fn features_scratch(
    lex: &Lexicon,
    text: &str,
    max_input_len: usize,
    scratch: &mut ScoreScratch,
) -> [f64; N_FEATURES] {
    tokenize_into(text, scratch);
    scratch.ids.clear();
    let table = &lex.compiled;

    // Per-class counters, folded from one lookup per token.
    let mut n_pp = 0usize; // ADP tags (structural)
    let mut n_rel = 0usize; // relativizer after a NOUN (structural)
    let mut n_ambig = 0usize; // noun/verb-ambiguous words (syntactic)
    let mut has_verb = false; // any VERB tag (syntactic)
    let mut semantic = 0.0f64; // homonym sense mass, in token order
    let mut n_topic = 0usize; // vague topics
    let mut n_vadj = 0usize; // vague adjectives
    let mut n_open = 0usize; // open-endedness markers
    let mut has_of = false; // literal "of" (open)
    let mut n_comma = 0usize; // "," tokens (multipart)
    let mut n_q = 0usize; // "?" tokens (multipart)
    let mut n_and = 0usize; // literal "and" (multipart, question-gated)
    let mut n_marker = 0usize; // multipart markers
    let mut first_open_wh = false; // first token opens a wh-question
    let mut first_wh = false; // first token is a wh-word
    let mut prev_tag = Tag::Other;

    let bytes = scratch.lower.as_bytes();
    for (i, &(start, end)) in scratch.spans.iter().enumerate() {
        let tok = &bytes[start..end];
        let hit = table.lookup(tok);
        scratch.ids.push(hit.map(|(id, _)| id).unwrap_or(NO_WORD));

        // Class-membership flags apply to every token — the legacy
        // scorers probe their sets with the token string regardless of
        // whether it is punctuation.
        if let Some((_, info)) = hit {
            if info.flags & FLAG_NV_AMBIG != 0 {
                n_ambig += 1;
            }
            if info.flags & FLAG_HOMONYM != 0 {
                // Same expression as the legacy scorer, summed in the
                // same token order.
                semantic += 3.0 * (info.senses - 1) as f64;
            }
            if info.flags & FLAG_VAGUE_TOPIC != 0 {
                n_topic += 1;
            }
            if info.flags & FLAG_VAGUE_ADJ != 0 {
                n_vadj += 1;
            }
            if info.flags & FLAG_OPEN_MARKER != 0 {
                n_open += 1;
            }
            if info.flags & FLAG_MULTIPART != 0 {
                n_marker += 1;
            }
            if info.flags & FLAG_RELATIVIZER != 0 && i > 0 && prev_tag == Tag::Noun {
                n_rel += 1;
            }
            if info.flags & FLAG_OF != 0 {
                has_of = true;
            }
            if info.flags & FLAG_AND != 0 {
                n_and += 1;
            }
            if i == 0 {
                first_open_wh = info.flags & FLAG_OPEN_WH != 0;
                first_wh = info.flags & FLAG_WH != 0;
            }
        }

        // Tagging order mirrors `pos_tag`: punctuation first, then the
        // PoS lexicon (folded into the same lookup), then suffix rules,
        // else NOUN.
        let tag = if is_punct_byte(tok[0]) {
            Tag::Punct
        } else {
            match hit {
                Some((_, info)) if info.flags & FLAG_POS != 0 => info.tag,
                _ => table.suffix_tag(tok),
            }
        };
        if tag == Tag::Adp {
            n_pp += 1;
        }
        if tag == Tag::Verb {
            has_verb = true;
        }
        prev_tag = tag;

        // Punctuation counts are plain string equality in the legacy
        // scorer; only a 1-byte token can equal "," or "?".
        if end - start == 1 {
            match tok[0] {
                b',' => n_comma += 1,
                b'?' => n_q += 1,
                _ => {}
            }
        }
    }

    // Post-pass folds, each mirroring its legacy scorer's accumulation
    // order exactly.
    let structural = 4.0 * n_pp.saturating_sub(1) as f64 + 2.0 * n_rel as f64;

    let mut syntactic = 3.0 * n_ambig as f64;
    if n_ambig > 0 && !has_verb {
        syntactic += 2.0;
    }

    let mut vague = 0.0;
    for phrase in table.vague_phrases() {
        if contains_phrase_ids(&scratch.ids, phrase) {
            vague += 5.0;
        }
    }
    vague += 4.0 * n_topic as f64;
    vague += 2.0 * n_vadj as f64;

    let mut open = 0.0;
    if first_open_wh {
        open += 3.0;
        if has_of {
            open += 2.0;
        }
    }
    open += 3.0 * n_open as f64;
    if contains_phrase_ids(&scratch.ids, table.think_phrase()) {
        open += 3.0;
    }

    let is_question = n_q > 0 || first_wh;
    if !is_question {
        n_and = 0;
    }
    let multipart = 2.0 * n_comma as f64
        + 2.0 * n_and as f64
        + 4.0 * n_q.saturating_sub(1) as f64
        + 3.0 * n_marker as f64;

    [
        structural,
        syntactic,
        semantic,
        vague,
        open,
        multipart,
        scratch.spans.len().min(max_input_len) as f64,
    ]
}
