//! RULEGEN — the six rule-based uncertainty scorers.
//!
//! Exact mirror of `python/compile/rulegen.py`; every count and
//! multiplier must stay identical (the goldens assert bit-equality of
//! the resulting f64s). See the python module for the linguistic
//! rationale of each rule.

use std::sync::OnceLock;

use crate::textgen::intern::THINK_PHRASE;
use crate::textgen::lexicon::{Lexicon, Tag};
use crate::textgen::pos::pos_tag;
use crate::textgen::tokenizer::tokenize;

/// Six rule scores + input length.
pub const N_FEATURES: usize = 7;

fn contains_phrase(tokens: &[String], phrase: &[String]) -> bool {
    if phrase.is_empty() || tokens.len() < phrase.len() {
        return false;
    }
    tokens
        .windows(phrase.len())
        .any(|w| w.iter().zip(phrase).all(|(a, b)| a == b))
}

/// PP-attachment chains + relative clauses.
pub fn structural_score(lex: &Lexicon, tokens: &[String], tags: &[Tag]) -> f64 {
    let n_pp = tags.iter().filter(|t| **t == Tag::Adp).count();
    let mut n_rel = 0usize;
    for (i, tok) in tokens.iter().enumerate() {
        if lex.relativizers.contains(tok.as_str()) && i > 0 && tags[i - 1] == Tag::Noun {
            n_rel += 1;
        }
    }
    4.0 * n_pp.saturating_sub(1) as f64 + 2.0 * n_rel as f64
}

/// Noun/verb-ambiguous words.
pub fn syntactic_score(lex: &Lexicon, tokens: &[String], tags: &[Tag]) -> f64 {
    let n_ambig = tokens.iter().filter(|t| lex.nv_ambiguous.contains(t.as_str())).count();
    let mut score = 3.0 * n_ambig as f64;
    if n_ambig > 0 && !tags.iter().any(|t| *t == Tag::Verb) {
        score += 2.0;
    }
    score
}

/// Homonyms weighted by sense count.
pub fn semantic_score(lex: &Lexicon, tokens: &[String], _tags: &[Tag]) -> f64 {
    tokens
        .iter()
        .filter_map(|t| lex.homonyms.get(t.as_str()))
        .map(|senses| 3.0 * (senses - 1) as f64)
        .sum()
}

/// Broad topics and "tell me about"-style prompts.
pub fn vague_score(lex: &Lexicon, tokens: &[String], _tags: &[Tag]) -> f64 {
    let mut score = 0.0;
    for phrase in &lex.vague_phrases {
        if contains_phrase(tokens, phrase) {
            score += 5.0;
        }
    }
    score += 4.0 * tokens.iter().filter(|t| lex.vague_topics.contains(t.as_str())).count() as f64;
    score += 2.0
        * tokens.iter().filter(|t| lex.vague_adjectives.contains(t.as_str())).count() as f64;
    score
}

/// Open-ended questions lacking a single definitive answer.
pub fn open_score(lex: &Lexicon, tokens: &[String], _tags: &[Tag]) -> f64 {
    let mut score = 0.0;
    if let Some(first) = tokens.first() {
        if lex.open_wh_starters.contains(first.as_str()) {
            score += 3.0;
            if tokens.iter().any(|t| t == "of") {
                score += 2.0;
            }
        }
    }
    score += 3.0 * tokens.iter().filter(|t| lex.open_markers.contains(t.as_str())).count() as f64;
    // Built once, not per call — this scorer runs on the admission hot
    // path (and doubles as the fast path's test oracle).
    static THINK: OnceLock<Vec<String>> = OnceLock::new();
    let think = THINK.get_or_init(|| THINK_PHRASE.iter().map(|s| s.to_string()).collect());
    if contains_phrase(tokens, think) {
        score += 3.0;
    }
    score
}

/// Multiple sub-questions/topics demanding compound answers.
pub fn multipart_score(lex: &Lexicon, tokens: &[String], _tags: &[Tag]) -> f64 {
    let n_comma = tokens.iter().filter(|t| t.as_str() == ",").count();
    let n_q = tokens.iter().filter(|t| t.as_str() == "?").count();
    let is_question = n_q > 0
        || tokens
            .first()
            .map(|t| lex.wh_words.contains(t.as_str()))
            .unwrap_or(false);
    let n_and = if is_question {
        tokens.iter().filter(|t| t.as_str() == "and").count()
    } else {
        0
    };
    let n_marker = tokens.iter().filter(|t| lex.multipart_markers.contains(t.as_str())).count();
    2.0 * n_comma as f64
        + 2.0 * n_and as f64
        + 4.0 * n_q.saturating_sub(1) as f64
        + 3.0 * n_marker as f64
}

/// Six raw rule scores for an input text.
pub fn rule_scores(lex: &Lexicon, text: &str) -> [f64; 6] {
    let tokens = tokenize(text);
    let tags = pos_tag(lex, &tokens);
    [
        structural_score(lex, &tokens, &tags),
        syntactic_score(lex, &tokens, &tags),
        semantic_score(lex, &tokens, &tags),
        vague_score(lex, &tokens, &tags),
        open_score(lex, &tokens, &tags),
        multipart_score(lex, &tokens, &tags),
    ]
}

/// Full feature vector: six scores + input length (clamped to
/// `max_input_len`, the manifest's truncation limit).
pub fn features(lex: &Lexicon, text: &str, max_input_len: usize) -> [f64; N_FEATURES] {
    let tokens = tokenize(text);
    let tags = pos_tag(lex, &tokens);
    [
        structural_score(lex, &tokens, &tags),
        syntactic_score(lex, &tokens, &tags),
        semantic_score(lex, &tokens, &tags),
        vague_score(lex, &tokens, &tags),
        open_score(lex, &tokens, &tags),
        multipart_score(lex, &tokens, &tags),
        tokens.len().min(max_input_len) as f64,
    ]
}

/// The paper's "single rule" heuristic (Fig. 2b): dominant rule score,
/// falling back to input length when no pattern fires.
pub fn single_rule_score(lex: &Lexicon, text: &str, max_input_len: usize) -> f64 {
    let f = features(lex, text, max_input_len);
    let best = f[..6].iter().copied().fold(0.0f64, f64::max);
    if best > 0.0 {
        best
    } else {
        f[6]
    }
}
