//! Native evaluation of the LW uncertainty regressor.
//!
//! The scheduling hot path runs the MLP directly in rust (a handful of
//! small matvecs, microseconds per task) instead of dispatching a PJRT
//! execution per request; the PJRT-executed HLO variant is kept for
//! validation (`runtime` tests assert both paths agree on the same
//! weights).

use anyhow::{anyhow, ensure, Result};

use crate::runtime::bundle::{Bundle, Dtype};

/// Weights of one dense layer (row-major [fan_in, fan_out]).
#[derive(Clone, Debug)]
struct Layer {
    w: Vec<f32>,
    b: Vec<f32>,
    fan_in: usize,
    fan_out: usize,
}

/// The native LW regressor: the trained MLP evaluated in pure rust on
/// the scheduling hot path (no PJRT round-trip per task).
#[derive(Clone, Debug)]
pub struct Regressor {
    layers: Vec<Layer>,
    feature_scales: Vec<f64>,
}

impl Regressor {
    /// Build from a tensor bundle with tensors named w0,b0,w1,b1,...
    pub fn from_bundle(bundle: &Bundle, feature_scales: &[f64]) -> Result<Regressor> {
        let mut layers = Vec::new();
        let mut i = 0;
        loop {
            let (Some(w), Some(b)) = (bundle.get(&format!("w{i}")), bundle.get(&format!("b{i}")))
            else {
                break;
            };
            ensure!(w.dtype == Dtype::F32 && b.dtype == Dtype::F32, "regressor weights must be f32");
            ensure!(w.dims.len() == 2 && b.dims.len() == 1, "bad regressor tensor ranks");
            ensure!(w.dims[1] == b.dims[0], "layer {i}: w/b shape mismatch");
            layers.push(Layer {
                w: w.as_f32()?.to_vec(),
                b: b.as_f32()?.to_vec(),
                fan_in: w.dims[0],
                fan_out: w.dims[1],
            });
            i += 1;
        }
        ensure!(!layers.is_empty(), "no regressor layers in bundle");
        ensure!(
            layers.last().unwrap().fan_out == 1,
            "regressor head must output 1 unit"
        );
        ensure!(
            layers[0].fan_in == feature_scales.len(),
            "feature count mismatch: regressor expects {}, scales have {}",
            layers[0].fan_in,
            feature_scales.len()
        );
        Ok(Regressor { layers, feature_scales: feature_scales.to_vec() })
    }

    /// Input feature count the first layer expects.
    pub fn n_features(&self) -> usize {
        self.layers[0].fan_in
    }

    /// Predict the output length for one raw (unnormalised) feature vector.
    pub fn predict(&self, raw_features: &[f64]) -> Result<f64> {
        self.predict_into(raw_features, &mut Vec::new(), &mut Vec::new())
    }

    /// [`Self::predict`] into caller-provided ping-pong activation
    /// buffers — the allocation-free variant the scoring fast path
    /// uses. The float operation sequence is identical to `predict`
    /// (same scaling, same sparse matvec skipping zero activations,
    /// same relu placement), so the result is bit-identical; the only
    /// difference is where the activations live. The buffers grow to
    /// the widest layer once, then are reused.
    pub fn predict_into(
        &self,
        raw_features: &[f64],
        h: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) -> Result<f64> {
        if raw_features.len() != self.n_features() {
            return Err(anyhow!(
                "expected {} features, got {}",
                self.n_features(),
                raw_features.len()
            ));
        }
        h.clear();
        h.extend(
            raw_features
                .iter()
                .zip(&self.feature_scales)
                .map(|(x, s)| (*x / *s) as f32),
        );
        let n_layers = self.layers.len();
        for (li, layer) in self.layers.iter().enumerate() {
            out.clear();
            out.extend_from_slice(&layer.b);
            for (i, &x) in h.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let row = &layer.w[i * layer.fan_out..(i + 1) * layer.fan_out];
                for (o, &wv) in out.iter_mut().zip(row) {
                    *o += x * wv;
                }
            }
            if li + 1 < n_layers {
                for o in out.iter_mut() {
                    *o = o.max(0.0);
                }
            }
            std::mem::swap(h, out);
        }
        Ok(h[0] as f64)
    }

    /// Batch predict (used by calibration / figure harness).
    pub fn predict_batch(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        rows.iter().map(|r| self.predict(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::bundle::{Bundle, Tensor};

    fn tiny_regressor() -> Regressor {
        // identity-ish: 2 features -> 1 output, w = [[1], [2]], b = [0.5]
        let bundle = Bundle::from_tensors(vec![
            Tensor::f32("w0", vec![2, 1], vec![1.0, 2.0]),
            Tensor::f32("b0", vec![1], vec![0.5]),
        ]);
        Regressor::from_bundle(&bundle, &[1.0, 1.0]).unwrap()
    }

    #[test]
    fn linear_layer_math() {
        let r = tiny_regressor();
        let y = r.predict(&[3.0, 4.0]).unwrap();
        assert!((y - (3.0 + 8.0 + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn feature_scaling_applied() {
        let bundle = Bundle::from_tensors(vec![
            Tensor::f32("w0", vec![1, 1], vec![1.0]),
            Tensor::f32("b0", vec![1], vec![0.0]),
        ]);
        let r = Regressor::from_bundle(&bundle, &[10.0]).unwrap();
        assert!((r.predict(&[5.0]).unwrap() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn relu_between_layers() {
        // layer0: y = -x (fan 1->1), relu clamps to 0; layer1: z = y + 7
        let bundle = Bundle::from_tensors(vec![
            Tensor::f32("w0", vec![1, 1], vec![-1.0]),
            Tensor::f32("b0", vec![1], vec![0.0]),
            Tensor::f32("w1", vec![1, 1], vec![1.0]),
            Tensor::f32("b1", vec![1], vec![7.0]),
        ]);
        let r = Regressor::from_bundle(&bundle, &[1.0]).unwrap();
        assert!((r.predict(&[5.0]).unwrap() - 7.0).abs() < 1e-6);
    }

    #[test]
    fn wrong_feature_count_errors() {
        let r = tiny_regressor();
        assert!(r.predict(&[1.0]).is_err());
        assert!(r.predict_into(&[1.0], &mut Vec::new(), &mut Vec::new()).is_err());
    }

    #[test]
    fn predict_into_matches_predict_bitwise() {
        let r = tiny_regressor();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for feats in [[3.0, 4.0], [0.0, 0.0], [-1.5, 2.5], [1e-9, 7.25]] {
            let want = r.predict(&feats).unwrap();
            let got = r.predict_into(&feats, &mut a, &mut b).unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "diverged on {feats:?}");
        }

        // multi-layer with relu and a width change: 2 -> 3 -> 1
        let bundle = Bundle::from_tensors(vec![
            Tensor::f32("w0", vec![2, 3], vec![0.3, -1.0, 2.0, 0.7, 0.1, -0.4]),
            Tensor::f32("b0", vec![3], vec![0.1, -0.2, 0.0]),
            Tensor::f32("w1", vec![3, 1], vec![1.5, -0.5, 0.25]),
            Tensor::f32("b1", vec![1], vec![0.05]),
        ]);
        let deep = Regressor::from_bundle(&bundle, &[10.0, 64.0]).unwrap();
        for feats in [[13.0, 9.0], [0.0, 31.0], [2.5, 0.0]] {
            let want = deep.predict(&feats).unwrap();
            let got = deep.predict_into(&feats, &mut a, &mut b).unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "diverged on {feats:?}");
        }
    }
}
