//! Application-level uncertainty quantification (paper Sec. III-B):
//! RULEGEN rule scorers, the LW regressor, and the combined estimator
//! that maps an input text to its uncertainty score (predicted output
//! length) on the scheduling hot path.

pub mod estimator;
pub mod fastpath;
pub mod regressor;
pub mod rules;

pub use estimator::Estimator;
pub use fastpath::features_scratch;
pub use regressor::Regressor;
pub use rules::{features, rule_scores, single_rule_score, N_FEATURES};
