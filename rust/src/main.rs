//! `rtlm` — the RT-LM coordinator CLI.
//!
//! Subcommands:
//!   check                 validate artifacts + run a smoke inference
//!   calibrate             measure PJRT latencies -> artifacts/calib.json
//!   bench <experiment>    regenerate a paper table/figure ('all' = every one)
//!   gauntlet              policy x scenario matrix -> deterministic JSON report
//!   sim                   one simulated serving run with printed summary
//!   serve                 real-mode serving run over a Poisson trace
//!   tcp                   interactive line-protocol TCP server
//!   route                 distributed-fleet router over registered tcp nodes
//!   loadgen               concurrent load test against a tcp server
//!   score <text..>        score a single utterance (features + u_J)

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use std::collections::BTreeMap;

use rtlm::bench_harness::scenarios::{run_experiment, ExperimentCtx, EXPERIMENTS};
use rtlm::config::{DeviceProfile, Manifest, ModelEntry, SchedMode, SchedParams, ShedPolicy};
use rtlm::executor::{modeled_factory, ExecutorFactory};
use rtlm::metrics::table::fmt_f;
use rtlm::model::LmSession;
use rtlm::runtime::ArtifactStore;
use rtlm::scheduler::{lane, LaneSet, PolicyKind};
use rtlm::server::{serve_from_root, serve_with_factory, ServeOptions};
use rtlm::sim::{Calibration, LatencyModel};
use rtlm::uncertainty::Estimator;
use rtlm::util::cli::Args;
use rtlm::workload::subsets::Variance;
use rtlm::workload::{corpus, subsets, ArrivalTrace, TaskFactory};

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_root(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Manifest::default_root)
}

/// Build the lane fleet from `--lanes` (inline grammar or `@file.json`),
/// defaulting to the historical two-lane gpu+cpu fleet. Thresholds may
/// be plain numbers, `inf`, `tau` (the computed offload threshold), or
/// `qP` quantiles of the workload's training scores (e.g. `q0.9`).
fn lanes_from_args(
    args: &Args,
    default_model: &str,
    tau: f64,
    train_scores: &mut rtlm::metrics::Samples,
) -> Result<LaneSet> {
    let Some(spec) = args.get("lanes") else {
        return Ok(LaneSet::two_lane(default_model, tau));
    };
    let mut resolve = |tok: &str| -> Result<f64> {
        match tok {
            "tau" => Ok(tau),
            _ if tok.starts_with('q') => {
                let p: f64 = tok[1..]
                    .parse()
                    .map_err(|_| anyhow!("bad quantile token '{tok}' (expected e.g. q0.9)"))?;
                Ok(train_scores.quantile(p))
            }
            _ => lane::numeric_thresholds(tok),
        }
    };
    if let Some(path) = spec.strip_prefix('@') {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading lane file {path}: {e}"))?;
        let json = rtlm::util::json::Json::parse(&text)
            .map_err(|e| anyhow!("parsing lane file {path}: {e}"))?;
        LaneSet::parse_json(&json, default_model, &mut resolve)
    } else {
        LaneSet::parse(spec, default_model, &mut resolve)
    }
}

/// Resolve every lane's model variant against the manifest.
fn lane_models(
    store: &ArtifactStore,
    lanes: &LaneSet,
) -> Result<BTreeMap<String, ModelEntry>> {
    let mut models = BTreeMap::new();
    for spec in lanes.iter() {
        if !models.contains_key(&spec.model) {
            models.insert(spec.model.clone(), store.manifest.model(&spec.model)?.clone());
        }
    }
    Ok(models)
}

/// The one place CLI flags become [`SchedParams`]: `sim`, `serve`,
/// `tcp`, `route`, and `bench` all funnel their base parameter set
/// through here. Applies the dispatch-mode flags (`--sched batch|step`,
/// `--slots N`, `--overrun-factor F`) and the overload admission knobs
/// (`--queue-cap N`, `--shed priority|length`) on top of whatever
/// defaults the caller built.
fn apply_sched_args(args: &Args, params: &mut SchedParams) -> Result<()> {
    params.mode = SchedMode::parse(args.get_or("sched", params.mode.label()))?;
    params.slots = args.get_usize("slots", params.slots)?;
    params.overrun_factor = args.get_f64("overrun-factor", params.overrun_factor)?;
    params.queue_cap = args.get_usize("queue-cap", params.queue_cap)?;
    params.shed = ShedPolicy::parse(args.get_or("shed", params.shed.label()))?;
    Ok(())
}

fn estimator_for(store: &Arc<ArtifactStore>) -> Estimator {
    let m = &store.manifest;
    Estimator::new(
        store.lexicon.clone(),
        store.regressor.clone(),
        m.max_input_len,
        m.min_output_len as f64,
        m.max_output_len as f64,
    )
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "check" => check(args),
        "calibrate" => calibrate(args),
        "bench" => bench(args),
        "gauntlet" => gauntlet(args),
        "sim" => sim(args),
        "serve" => serve_cmd(args),
        "tcp" => tcp(args),
        "route" => route(args),
        "loadgen" => loadgen(args),
        "score" => score(args),
        _ => {
            println!("{}", rtlm::util::cli::help_text(EXPERIMENTS));
            Ok(())
        }
    }
}

fn check(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    println!("artifacts: {}", root.display());
    let store = Arc::new(ArtifactStore::open(&root)?);
    let m = &store.manifest;
    println!(
        "manifest ok: {} models, vocab {}, seq_max {}, quick={}",
        m.models.len(),
        m.vocab_size,
        m.seq_max,
        m.quick
    );
    let est = estimator_for(&store);
    let demo = "What are the causes and consequences of poverty in developing countries?";
    let (u, feats) = est.score_with_features(demo)?;
    println!("score(\"{demo}\") = {u:.1} tokens, features {feats:?}");

    match store.client() {
        Ok(client) => {
            println!("PJRT platform: {}", client.platform_name());
            let model =
                m.model_names().into_iter().next().ok_or_else(|| anyhow!("no models"))?;
            let session = LmSession::new(store.clone(), &model)?;
            let prompt = rtlm::model::session::encode_prompt(&store, demo);
            let out = session.generate(&[prompt], &[8])?;
            println!(
                "smoke inference on {model}: 8 tokens in {:.1} ms prefill + {:.1} ms decode -> \"{}\"",
                out.prefill_secs * 1e3,
                out.decode_secs * 1e3,
                store.vocab.decode(&out.tokens[0])
            );
        }
        Err(e) => println!("PJRT unavailable ({e:#}); skipping smoke inference"),
    }
    println!("check OK");
    Ok(())
}

fn calibrate(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let store = Arc::new(ArtifactStore::open(&root)?);
    let reps = args.get_usize("reps", 5)?;
    let mut calib = Calibration {
        note: format!("cpu-pjrt reps={reps}"),
        ..Default::default()
    };

    // regressor native latency
    let est = estimator_for(&store);
    let t0 = std::time::Instant::now();
    let n_reg = 2000;
    for i in 0..n_reg {
        let _ = est.score_features(&[1.0, 2.0, 3.0, 0.0, 5.0, 1.0, (i % 40) as f64])?;
    }
    calib.regressor_secs = t0.elapsed().as_secs_f64() / n_reg as f64;
    println!("regressor: {:.1} us/task", calib.regressor_secs * 1e6);

    for name in store.manifest.model_names() {
        println!("calibrating {name}...");
        let session = LmSession::new(store.clone(), &name)?;
        let entry = store.manifest.model(&name)?.clone();
        let mut decode = std::collections::BTreeMap::new();
        for &b in entry.decode.keys() {
            let secs = session.time_decode_step(b, reps)?;
            println!("  decode b={b}: {:.2} ms/step", secs * 1e3);
            decode.insert(b, secs);
        }
        // physical-consistency smoothing: a bigger batch is never faster
        // than a smaller one, and never worse than linear in rows.
        let mut prev: Option<(usize, f64)> = None;
        for (&b, secs) in decode.iter_mut() {
            if let Some((pb, pt)) = prev {
                *secs = secs.max(pt).min(pt * b as f64 / pb as f64);
            }
            prev = Some((b, *secs));
        }
        calib.decode.insert(name.clone(), decode);
        let mut prefill = std::collections::BTreeMap::new();
        for &bucket in entry.prefill.keys() {
            let secs = session.time_prefill(bucket, reps)?;
            println!("  prefill b={} s={}: {:.2} ms", bucket.0, bucket.1, secs * 1e3);
            prefill.insert(bucket, secs);
        }
        calib.prefill.insert(name.clone(), prefill);
    }

    let path = root.join("calib.json");
    calib.save(&path)?;
    println!("wrote {}", path.display());
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let store = Arc::new(ArtifactStore::open(&root)?);
    // `--wire FILTER` parses as an option; a bare trailing `--wire` as a
    // flag — accept both
    if args.flag("wire") || args.get("wire").is_some() {
        return bench_wire(args, store);
    }
    let n = args.get_usize("n", 400)?;
    let seed = args.get_u64("seed", 7)?;
    let mut ctx = ExperimentCtx::new(store, n, seed)?;
    // every cell clones its params off the ctx baseline, so the shared
    // builder applies CLI sched/shed knobs to the whole experiment grid
    apply_sched_args(args, &mut ctx.params)?;
    let exp = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    run_experiment(&ctx, exp)
}

/// `rtlm gauntlet`: run the policy × scenario matrix (artifact-free —
/// synthetic seeded traces, stub model, hand-built calibration) on the
/// virtual clock, wire-replay the `--wire` subset, print the per-cell
/// SLO-attainment table, and write the deterministic JSON report
/// consumed by `scripts/gauntlet_report.py`. Nonzero exit on any cell
/// error or wire parity failure (the CI gauntlet gate).
fn gauntlet(args: &Args) -> Result<()> {
    use rtlm::bench_harness::gauntlet::{
        gauntlet_json, render_gauntlet, run_gauntlet, GauntletConfig, Scenario,
    };

    let mut cfg = GauntletConfig {
        n: args.get_usize("n", 48)?,
        seed: args.get_u64("seed", 7)?,
        time_scale: args.get_f64("time-scale", 25.0)?,
        ..Default::default()
    };
    if let Some(spec) = args.get("policies") {
        cfg.policies =
            spec.split(',').map(PolicyKind::parse).collect::<Result<Vec<_>>>()?;
    }
    if let Some(spec) = args.get("scenarios") {
        cfg.scenarios = if spec == "all" {
            Scenario::ALL.to_vec()
        } else {
            spec.split(',').map(Scenario::parse).collect::<Result<Vec<_>>>()?
        };
    }
    if let Some(spec) = args.get("wire") {
        cfg.wire = if spec == "all" {
            cfg.scenarios.clone()
        } else {
            spec.split(',').map(Scenario::parse).collect::<Result<Vec<_>>>()?
        };
    }
    if cfg.policies.is_empty() || cfg.scenarios.is_empty() {
        return Err(anyhow!("gauntlet needs at least one policy and one scenario"));
    }

    println!(
        "gauntlet: {} scenario(s) x {} policy(ies), n={} seed={}{}",
        cfg.scenarios.len(),
        cfg.policies.len(),
        cfg.n,
        cfg.seed,
        if cfg.wire.is_empty() {
            String::new()
        } else {
            format!(", wire-replaying {} scenario(s) at {}x", cfg.wire.len(), cfg.time_scale)
        }
    );
    let cells = run_gauntlet(&cfg);
    print!("{}", render_gauntlet(&cells));
    if let Some(path) = args.get("out") {
        std::fs::write(path, gauntlet_json(&cfg, &cells).to_string())?;
        println!("gauntlet report written to {path}");
    }
    let bad = cells.iter().filter(|c| !c.clean()).count();
    if bad > 0 {
        return Err(anyhow!("gauntlet failed on {bad} of {} cells", cells.len()));
    }
    println!("gauntlet clean on all {} cells", cells.len());
    Ok(())
}

/// `rtlm bench --wire`: replay the internal comparison cells on the
/// virtual-clock and threaded backends, diff each pair of reports, and
/// exit nonzero unless every cell is clean (the CI parity gate).
fn bench_wire(args: &Args, store: Arc<ArtifactStore>) -> Result<()> {
    use rtlm::bench_harness::internal::parity_cells;
    use rtlm::bench_harness::replay::{parity_json, render_parity, run_parity, ParityTolerance};

    // wire replays run each cell twice (and the threaded one in real,
    // if compressed, time): default to a leaner grid than `bench`
    let n = args.get_usize("n", 64)?;
    let seed = args.get_u64("seed", 7)?;
    let time_scale = args.get_f64("time-scale", 25.0)?;
    let mut ctx = ExperimentCtx::new(store, n, seed)?;
    apply_sched_args(args, &mut ctx.params)?;
    let mut tol = ParityTolerance::for_time_scale(time_scale);
    tol.rel = args.get_f64("parity-rel", tol.rel)?;
    // the wall-slop default (and its dilation rule) lives in
    // ParityTolerance; only rebuild when the flag is explicitly given
    if args.get("parity-slop-ms").is_some() {
        tol = ParityTolerance::new(tol.rel, args.get_f64("parity-slop-ms", 0.0)?, time_scale);
    }
    let filter = args
        .get("wire")
        .or_else(|| args.positional.get(1).map(String::as_str))
        .filter(|f| *f != "all");

    let mut reports = Vec::new();
    for cell in parity_cells(&ctx, filter)? {
        println!(
            "replaying {} ({}, {} tasks) on both backends at {time_scale}x...",
            cell.label,
            cell.kind.label(),
            cell.tasks.len()
        );
        let parity = run_parity(&cell, &ctx.lat, time_scale, &tol)?;
        for failure in &parity.failures {
            eprintln!("  parity failure: {failure}");
        }
        reports.push(parity);
    }
    if reports.is_empty() {
        return Err(anyhow!("no parity cell matched filter {filter:?}"));
    }
    println!();
    print!("{}", render_parity(&reports));
    if let Some(path) = args.get("parity-out") {
        std::fs::write(path, parity_json(time_scale, &tol, &reports).to_string())?;
        println!("parity report written to {path}");
    }
    let failed = reports.iter().filter(|c| !c.clean()).count();
    if failed > 0 {
        return Err(anyhow!(
            "wire parity failed on {failed} of {} cells (sim and threaded engine disagree)",
            reports.len()
        ));
    }
    println!("wire parity clean on all {} cells", reports.len());
    Ok(())
}

fn sim(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let store = Arc::new(ArtifactStore::open(&root)?);
    let n = args.get_usize("n", 400)?;
    let seed = args.get_u64("seed", 7)?;
    let ctx = ExperimentCtx::new(store, n, seed)?;
    let model_name = args.get_or("model", "dialogpt").to_string();
    let model = ctx.model(&model_name)?;
    let kind = PolicyKind::parse(args.get_or("policy", "rtlm"))?;
    let dev = DeviceProfile::by_name(args.get_or("device", "edge-server"))?;
    let variance = match args.get_or("variance", "normal") {
        "small" => Variance::Small,
        "large" => Variance::Large,
        _ => Variance::Normal,
    };
    let tasks = ctx.scenario_tasks(model, variance, seed)?;
    let mut cell = ctx.cell(model, tasks, kind, &dev);
    apply_sched_args(args, &mut cell.params)?;
    let mode = cell.params.mode;
    let r = cell.run_sim(&ctx.lat)?;
    let mut s = r.response_times();
    let mut ttft = r.ttft_times();
    println!(
        "sim: model={model_name} policy={} device={} n={} variance={:?} sched={}",
        kind.label(),
        dev.name,
        n,
        variance,
        mode.label()
    );
    println!(
        "response time s: mean {} p50 {} p95 {} max {} | ttft p95 {}",
        fmt_f(s.mean(), 3),
        fmt_f(s.p50(), 3),
        fmt_f(s.p95(), 3),
        fmt_f(s.max(), 3),
        fmt_f(ttft.p95(), 3)
    );
    println!(
        "throughput {}/min  misses {} ({:.1}%)  batches {}  steps {}  preempted {}  shed {}  sched {:.1} us/task",
        fmt_f(r.throughput_per_min(), 1),
        r.miss_count(),
        r.miss_rate() * 100.0,
        r.fmt_batches(),
        r.n_steps.iter().sum::<usize>(),
        r.n_preempted,
        r.n_shed,
        r.sched_wall_secs / r.outcomes.len().max(1) as f64 * 1e6,
    );
    if let Some(path) = args.get("export") {
        r.export_jsonl(std::path::Path::new(path))?;
        println!("per-task outcomes exported to {path}");
    }
    Ok(())
}

fn serve_cmd(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let store = Arc::new(ArtifactStore::open(&root)?);
    let n = args.get_usize("n", 48)?;
    let seed = args.get_u64("seed", 7)?;
    let model_name = args.get_or("model", "t5").to_string();
    let kind = PolicyKind::parse(args.get_or("policy", "rtlm"))?;
    let time_scale = args.get_f64("time-scale", 20.0)?;
    let beta = args.get_f64("beta", 120.0)?;
    let variance = match args.get_or("variance", "normal") {
        "small" => Variance::Small,
        "large" => Variance::Large,
        _ => Variance::Normal,
    };

    let est = estimator_for(&store);
    let items = corpus::load_many(store.manifest.corpus_test.values())?;
    let scores: Vec<f64> = items
        .iter()
        .map(|i| est.score_features(&i.features))
        .collect::<Result<_>>()?;
    let chosen = subsets::select(&items, &scores, variance, n, seed);
    let trace = ArrivalTrace::poisson_fixed(n, beta, seed);
    let model = store.manifest.model(&model_name)?.clone();
    let mut factory = TaskFactory::new(est, 2.0);
    let mut tasks = factory.build_all(&chosen, &trace, &model, false)?;
    rtlm::server::engine::encode_prompts(&store, &mut tasks);

    // offline decisions
    let lat = LatencyModel::load_or_analytic(&store.manifest)?;
    let mut train_scores = rtlm::metrics::Samples::from_vec(scores);
    let mut params = SchedParams {
        batch_size: rtlm::bench_harness::scenarios::optimal_batch(&lat, &model_name),
        ..Default::default()
    };
    apply_sched_args(args, &mut params)?;
    let tau = train_scores.quantile(params.k);
    let lanes = lanes_from_args(args, &model_name, tau, &mut train_scores)?;
    // UP priorities estimate execution time with the coefficient of the
    // model the primary lane actually serves (which --lanes may have
    // pointed away from --model)
    let primary_eta = store.manifest.model(&lanes.spec(lanes.primary()).model)?.eta;
    let mut policy = kind.build(&params, primary_eta, &lanes);

    let backend = args.get_or("backend", "pjrt").to_string();
    println!(
        "real serve: model={model_name} policy={} n={n} beta={beta}/min time-scale={time_scale}x C={} sched={} backend={backend} lanes={}",
        kind.label(),
        params.batch_size,
        params.mode.label(),
        lanes.names().join(",")
    );
    let opts = ServeOptions { time_scale, verbose: args.flag("verbose"), ..Default::default() };
    let report = match backend.as_str() {
        "pjrt" => serve_from_root(&root, &lanes, tasks, &mut *policy, &params, &opts)?,
        // full wire path — threads, channels, ξ deadlines — with batch
        // durations from the calibrated latency model: no PJRT backend
        // and no model artifacts needed beyond the manifest pipeline
        "modeled" | "sim" => {
            let dev = DeviceProfile::by_name(args.get_or("device", "edge-server"))?;
            let models = lane_models(&store, &lanes)?;
            let factory = modeled_factory(lat.clone(), models, dev, time_scale);
            serve_with_factory(tasks, &mut *policy, &params, &lanes, &opts, factory)?
        }
        other => return Err(anyhow!("unknown serve backend '{other}' (pjrt | modeled)")),
    };
    let mut s = report.response_times();
    let mut ttft = report.ttft_times();
    println!(
        "completed {} tasks in {:.1}s wall | response s: mean {} p50 {} p95 {} max {} | ttft p95 {}",
        report.outcomes.len(),
        report.wall_secs,
        fmt_f(s.mean(), 3),
        fmt_f(s.p50(), 3),
        fmt_f(s.p95(), 3),
        fmt_f(s.max(), 3),
        fmt_f(ttft.p95(), 3)
    );
    println!(
        "throughput {}/min | batches {} | steps {} | preempted {} | shed {} | infer {:.1}s | sched {:.1} us/task",
        fmt_f(report.throughput_per_min(), 1),
        report.fmt_batches(),
        report.n_steps.iter().sum::<usize>(),
        report.n_preempted,
        report.n_shed,
        report.infer_secs,
        report.sched_secs / report.outcomes.len().max(1) as f64 * 1e6
    );
    if args.flag("require-all-lanes") {
        let starved: Vec<&str> = report
            .lanes
            .iter()
            .zip(&report.n_batches)
            .filter(|(_, &c)| c == 0)
            .map(|(name, _)| name.as_str())
            .collect();
        if !starved.is_empty() {
            return Err(anyhow!(
                "lanes executed no batch: {} (batches {})",
                starved.join(", "),
                report.fmt_batches()
            ));
        }
        println!("every configured lane executed >= 1 batch");
    }
    Ok(())
}

fn tcp(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let store = Arc::new(ArtifactStore::open(&root)?);
    let model_name = args.get_or("model", "t5").to_string();
    let addr = args.get_or("addr", "127.0.0.1:7490").to_string();
    let kind = PolicyKind::parse(args.get_or("policy", "rtlm"))?;
    let pipeline = args.get_usize("pipeline", 1)?.max(1);
    let est = estimator_for(&store);

    let items = corpus::load_many(store.manifest.corpus_train.values())?;
    let scores: Vec<f64> = items
        .iter()
        .map(|i| est.score_features(&i.features))
        .collect::<Result<_>>()?;
    let mut s = rtlm::metrics::Samples::from_vec(scores);
    let mut params = SchedParams { batch_size: 4, xi: 0.25, ..Default::default() };
    apply_sched_args(args, &mut params)?;
    let tau = s.quantile(params.k);
    let lanes = lanes_from_args(args, &model_name, tau, &mut s)?;
    // eta (like phi in TcpServerConfig::from_store) comes from the
    // model the primary lane actually serves
    let primary_eta = store.manifest.model(&lanes.spec(lanes.primary()).model)?.eta;
    let policy = kind.build(&params, primary_eta, &lanes);

    // executors are built inside their lane worker threads (PJRT
    // handles are not Send), so every lane serves genuinely concurrently
    let factory: ExecutorFactory = match args.get_or("backend", "pjrt") {
        "pjrt" => rtlm::server::engine::pjrt_factory(&root),
        // backend-free serving smoke: modeled latencies, empty outputs
        "modeled" | "sim" => modeled_factory(
            LatencyModel::load_or_analytic(&store.manifest)?,
            lane_models(&store, &lanes)?,
            DeviceProfile::by_name(args.get_or("device", "edge-server"))?,
            args.get_f64("time-scale", 1.0)?,
        ),
        other => return Err(anyhow!("unknown tcp backend '{other}' (pjrt | modeled)")),
    };
    let mut cfg =
        rtlm::server::tcp::TcpServerConfig::from_store(&store, est, lanes, params, pipeline)?;
    cfg.node = args.get_or("node-name", &cfg.node).to_string();
    cfg.register = args.get("register").map(str::to_string);
    rtlm::server::tcp::serve_tcp(cfg, factory, policy, &addr)
}

/// `rtlm route`: the distributed-fleet controller. Gathers the lane
/// tables of every node (dialed via `--nodes` and/or registering via
/// their `--register` flag), unions them into one `node/lane` fleet,
/// and serves the same line protocol as `rtlm tcp` — scoring
/// uncertainty once at admission and routing across the union with the
/// chosen policy. Each remote lane's batches travel over a framed TCP
/// stream to its node; heartbeat monitors evict dead nodes and their
/// in-flight tasks re-queue through ordinary lane admission.
fn route(args: &Args) -> Result<()> {
    use rtlm::server::router;
    use std::time::Duration;

    let root = artifacts_root(args);
    let store = Arc::new(ArtifactStore::open(&root)?);
    let addr = args.get_or("addr", "127.0.0.1:7500").to_string();
    let kind = PolicyKind::parse(args.get_or("policy", "rtlm"))?;
    let pipeline = args.get_usize("pipeline", 1)?.max(1);
    let heartbeat = Duration::from_secs_f64(args.get_f64("heartbeat-s", 2.0)?.max(0.05));
    let expect_nodes = args.get_usize("expect-nodes", 0)?;
    let static_addrs: Vec<String> = args
        .get("nodes")
        .map(|s| {
            s.split(',')
                .map(str::trim)
                .filter(|a| !a.is_empty())
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    if static_addrs.is_empty() && expect_nodes == 0 {
        return Err(anyhow!(
            "no nodes to route over: pass --nodes host:port[,host:port..] and/or \
             --expect-nodes N (nodes started with --register {addr})"
        ));
    }

    let listener = std::net::TcpListener::bind(&addr)
        .map_err(|e| anyhow!("binding {addr}: {e}"))?;
    println!(
        "router on {addr}: dialing {} static node(s), waiting for {expect_nodes} registration(s)",
        static_addrs.len()
    );
    let nodes =
        router::gather_nodes(&static_addrs, &listener, expect_nodes, Duration::from_secs(30))?;
    let lanes = router::union_fleet(&nodes)?;
    for node in &nodes {
        println!(
            "  node {} at {}: {} lane(s) [{}]",
            node.name,
            node.addr,
            node.lanes.len(),
            node.lanes.iter().map(|l| l.name.as_str()).collect::<Vec<_>>().join(",")
        );
    }

    let est = estimator_for(&store);
    let mut params = SchedParams { batch_size: 4, xi: 0.25, ..Default::default() };
    apply_sched_args(args, &mut params)?;
    // UP priorities estimate execution time with the coefficient of the
    // model the primary union lane actually serves (gossiped by its
    // node; the router's manifest must know the same model names)
    let primary_eta = store.manifest.model(&lanes.spec(lanes.primary()).model)?.eta;
    let policy = kind.build(&params, primary_eta, &lanes);

    let registry = router::new_registry();
    let factory = router::remote_factory(&nodes, registry.clone());
    let mut cfg = rtlm::server::tcp::TcpServerConfig::from_store(
        &store,
        est,
        lanes.clone(),
        params,
        pipeline,
    )?;
    cfg.node = args.get_or("node-name", "router").to_string();
    println!(
        "routing policy={} over {} union lanes: {}",
        kind.label(),
        lanes.names().len(),
        lanes.names().join(",")
    );
    rtlm::server::tcp::serve_tcp_with(listener, cfg, factory, policy, |handle| {
        router::spawn_monitors(&nodes, &lanes, handle, heartbeat, &registry);
    })
}

fn loadgen(args: &Args) -> Result<()> {
    use rtlm::server::loadgen::{run, LoadgenOptions};

    let addr = args.get_or("addr", "127.0.0.1:7490").to_string();
    let n = args.get_usize("n", 200)?;
    let opts = LoadgenOptions {
        n,
        concurrency: args.get_usize("concurrency", n)?,
        reply_timeout: std::time::Duration::from_secs_f64(args.get_f64("timeout-s", 60.0)?),
        connect_wait: std::time::Duration::from_secs_f64(args.get_f64("connect-wait-s", 30.0)?),
        rate: args.get_f64("rate", 0.0)?,
    };
    if opts.rate > 0.0 {
        println!(
            "loadgen: {n} requests over {} connections against {addr} (open loop, {} req/s)",
            opts.concurrency, opts.rate
        );
    } else {
        println!(
            "loadgen: {n} requests over {} connections against {addr}",
            opts.concurrency
        );
    }
    let mut report = run(&addr, &opts)?;
    let (mean, p50, p95, max) = (
        report.response_ms.mean(),
        report.response_ms.p50(),
        report.response_ms.p95(),
        report.response_ms.max(),
    );
    println!(
        "ok {} / shed {} / err {} | server response_ms: mean {} p50 {} p95 {} max {} | ttft_ms p95 {} | client rtt_ms p95 {}",
        report.n_ok,
        report.n_shed,
        report.n_err,
        fmt_f(mean, 1),
        fmt_f(p50, 1),
        fmt_f(p95, 1),
        fmt_f(max, 1),
        fmt_f(report.ttft_ms.p95(), 1),
        fmt_f(report.rtt_ms.p95(), 1),
    );
    if !report.lane_tasks.is_empty() {
        println!("per-lane tasks: {}", report.fmt_lane_tasks());
    }
    if !report.node_tasks.is_empty() {
        println!("per-node tasks: {}", report.fmt_node_tasks());
    }
    for e in &report.errors {
        eprintln!("  error: {e}");
    }
    if args.flag("allow-server-errors") {
        // chaos-gate mode: a node died mid-run, so id-tagged server
        // error replies are acceptable — but every request must still
        // get *some* answer (no lost ids), and nothing else may fail
        let answered = report.n_ok + report.n_server_err + report.n_shed;
        if answered != n || report.n_err != report.n_server_err {
            return Err(anyhow!(
                "load test failed: {} of {n} requests answered ({} ok + {} shed + {} server \
                 errors), {} non-server errors",
                answered,
                report.n_ok,
                report.n_shed,
                report.n_server_err,
                report.n_err - report.n_server_err
            ));
        }
        println!(
            "all {n} requests answered: {} ok, {} shed, {} server error replies (allowed)",
            report.n_ok, report.n_shed, report.n_server_err
        );
    } else if report.n_err > 0 || report.n_ok + report.n_shed != n {
        // sheds are answered requests — the exactly-one-reply invariant
        // counts them; only errors and lost replies fail the run
        return Err(anyhow!(
            "load test failed: {} errors, {} ok + {} shed of {n} requests answered",
            report.n_err,
            report.n_ok,
            report.n_shed
        ));
    }
    let min_shed = args.get_usize("min-shed", 0)?;
    if report.n_shed < min_shed {
        return Err(anyhow!(
            "only {} requests shed, expected at least {min_shed} (overload did not bind)",
            report.n_shed
        ));
    }
    if let Some(bound) = args.get("max-shed-rate") {
        let bound: f64 = bound
            .parse()
            .map_err(|_| anyhow!("--max-shed-rate expects a fraction, got '{bound}'"))?;
        let rate = report.n_shed as f64 / n as f64;
        if rate > bound {
            return Err(anyhow!(
                "shed rate {rate:.3} ({} of {n}) exceeds the {bound:.3} bound",
                report.n_shed
            ));
        }
        println!("shed rate {rate:.3} within the {bound:.3} bound");
    }
    if let Some(expect) = args.get("expect-lanes") {
        let missing: Vec<&str> = expect
            .split(',')
            .map(str::trim)
            .filter(|l| !l.is_empty() && report.lane_tasks.get(*l).copied().unwrap_or(0) == 0)
            .collect();
        if !missing.is_empty() {
            return Err(anyhow!(
                "lanes served no task: {} (per-lane tasks: {})",
                missing.join(", "),
                report.fmt_lane_tasks()
            ));
        }
        println!("every expected lane served >= 1 task");
    }
    if let Some(bound) = args.get("p95-ms") {
        let bound: f64 = bound
            .parse()
            .map_err(|_| anyhow!("--p95-ms expects a number, got '{bound}'"))?;
        if p95 > bound {
            return Err(anyhow!("p95 response_ms {p95:.1} exceeds the {bound:.1} ms bound"));
        }
        println!("p95 {p95:.1} ms within the {bound:.1} ms bound");
    }
    Ok(())
}

fn score(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let store = Arc::new(ArtifactStore::open(&root)?);
    let text = args.positional[1..].join(" ");
    if text.is_empty() {
        return Err(anyhow!("usage: rtlm score <text...>"));
    }
    let est = estimator_for(&store);
    let (u, feats) = est.score_with_features(&text)?;
    let names = &store.manifest.feature_names;
    println!("text: {text}");
    for (name, value) in names.iter().zip(feats.iter()) {
        println!("  {name:<12} {value:>7.2}");
    }
    println!("uncertainty score (predicted output tokens): {u:.1}");
    for (name, entry) in &store.manifest.models {
        println!("  est. latency on {name:<11}: {:>6.1} ms", entry.eta * u * 1e3);
    }
    Ok(())
}
