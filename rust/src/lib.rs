//! RT-LM — uncertainty-aware resource management for real-time LM serving.
//!
//! Reproduction of "RT-LM: Uncertainty-Aware Resource Management for
//! Real-Time Inference of Language Models" (Li et al., 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's system contribution: the
//!   uncertainty-aware scheduler ([`scheduler`]), dual execution lanes
//!   ([`executor`]), workload engine ([`workload`]), real-time serving
//!   loop ([`server`]) and the calibrated discrete-event simulator
//!   ([`sim`]) used to regenerate the paper's tables and figures.
//! - **L2/L1 (build-time python)** — the transformer LM variants and the
//!   Pallas kernels, AOT-lowered to HLO text in `artifacts/` and executed
//!   through [`runtime`] (PJRT CPU client; python never runs at serve
//!   time).
//!
//! See `DESIGN.md` for the paper-to-module map and the substitutions made
//! for unavailable hardware/data.

// Every public item carries docs; CI promotes this (and rustdoc's
// broken-intra-doc-link lints) to errors via -D warnings.
#![warn(missing_docs)]

pub mod bench_harness;
pub mod config;
pub mod engine;
pub mod executor;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod textgen;
pub mod uncertainty;
pub mod util;
pub mod workload;
