//! Adversarial "malicious" tasks (Sec. V-G).
//!
//! The paper crafts inputs with a white-box attack ([56]) that elongates
//! LM outputs. The scheduler only observes the *consequence* — a task
//! whose uncertainty features and true execution time are inflated — so
//! the substitution appends maximally-open/multi-part clauses (raising
//! the RULEGEN scores the same way the attack raises true uncertainty)
//! and scales the oracle length accordingly.

use crate::util::rng::Pcg64;

use super::corpus::WorkItem;

/// Output-length inflation factor for crafted tasks (Table V shows the
/// attack roughly doubling-to-tripling response length).
pub const LENGTH_FACTOR: f64 = 2.4;

const TOPICS: [&str; 6] = ["art", "history", "society", "technology", "life", "culture"];
const PAIRS: [(&str, &str); 4] =
    [("cats", "dogs"), ("books", "movies"), ("cities", "villages"), ("coffee", "tea")];
const ASPECTS: [&str; 6] = ["behavior", "diet", "culture", "cost", "history", "size"];

/// Craft a malicious variant of a work item: adversarially suffixed
/// text + inflated oracle lengths.
pub fn craft(item: &WorkItem, max_output_len: usize, rng: &mut Pcg64) -> WorkItem {
    let topic = rng.choice(&TOPICS);
    let topic2 = rng.choice(&TOPICS);
    let (a, b) = rng.choice(&PAIRS);
    let asp1 = rng.choice(&ASPECTS);
    let asp2 = rng.choice(&ASPECTS);
    let suffix = format!(
        " also , tell me about the {topic} of {topic2} , and what are the causes and \
         consequences of {topic} ? how do {a} and {b} compare in {asp1} , {asp2} , and more ?"
    );
    let mut crafted = item.clone();
    crafted.text.push_str(&suffix);
    let inflate = |l: usize| -> usize {
        (((l as f64) * LENGTH_FACTOR).round() as usize).min(max_output_len)
    };
    crafted.base_len = inflate(item.base_len);
    for len in crafted.lens.values_mut() {
        *len = (((*len as f64) * LENGTH_FACTOR).round() as usize).min(max_output_len);
    }
    // features are stale after the text edit; the task factory rescoring
    // path recomputes them, but keep them monotone for feature-driven
    // callers too.
    crafted.features = vec![];
    crafted
}

/// Replace a `ratio` fraction of items (chosen at random) with crafted
/// variants. Returns the new list and how many were crafted.
pub fn inject(
    items: &[WorkItem],
    ratio: f64,
    max_output_len: usize,
    seed: u64,
) -> (Vec<WorkItem>, usize) {
    let mut rng = Pcg64::new(seed ^ 0xBADC0DE);
    let n_malicious = ((items.len() as f64) * ratio.clamp(0.0, 1.0)).round() as usize;
    let mut idx: Vec<usize> = (0..items.len()).collect();
    rng.shuffle(&mut idx);
    let mut out = items.to_vec();
    for &i in idx.iter().take(n_malicious) {
        out[i] = craft(&items[i], max_output_len, &mut rng);
    }
    (out, n_malicious)
}

/// Marks which outputs of [`inject`] were crafted (text-based, used by
/// the task factory to set `Task::malicious`).
pub fn is_crafted(item: &WorkItem) -> bool {
    item.features.is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn item() -> WorkItem {
        WorkItem {
            text: "i love pizza .".into(),
            utype: "plain".into(),
            input_len: 4,
            base_len: 12,
            lens: BTreeMap::from([("t5".to_string(), 10)]),
            features: vec![0.0; 7],
        }
    }

    #[test]
    fn craft_inflates_lengths() {
        let mut rng = Pcg64::new(0);
        let crafted = craft(&item(), 96, &mut rng);
        assert!(crafted.base_len > 12);
        assert_eq!(crafted.base_len, 29); // 12 * 2.4 = 28.8 -> 29
        assert_eq!(crafted.lens["t5"], 24);
        assert!(crafted.text.len() > item().text.len());
        assert!(is_crafted(&crafted));
    }

    #[test]
    fn craft_clamps_to_max() {
        let mut big = item();
        big.base_len = 90;
        let mut rng = Pcg64::new(0);
        let crafted = craft(&big, 96, &mut rng);
        assert_eq!(crafted.base_len, 96);
    }

    #[test]
    fn inject_ratio_respected() {
        let items = vec![item(); 100];
        for ratio in [0.0, 0.3, 1.0] {
            let (out, n) = inject(&items, ratio, 96, 5);
            assert_eq!(n, (100.0 * ratio) as usize);
            assert_eq!(out.iter().filter(|i| is_crafted(i)).count(), n);
        }
    }
}
