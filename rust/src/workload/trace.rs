//! Arrival traces and length mixes (Sec. V-A and the scenario
//! gauntlet). The paper's workload is a Poisson process whose rate beta
//! (queries/minute) sweeps 10..150 one minute at a time; the gauntlet
//! adds diurnal/bursty [Markov-modulated Poisson](ArrivalTrace::mmpp)
//! arrivals, [flash-crowd spikes](ArrivalTrace::flash_crowd), and
//! heavy-tailed ([`LengthDist`]) prompt/output-length mixes. Every
//! generator is seeded and bit-reproducible: same seed, same trace.

use crate::util::rng::Pcg64;

/// One phase of a Markov-modulated Poisson process: a mean arrival
/// rate held for a fixed span of trace time.
#[derive(Clone, Copy, Debug)]
pub struct MmppPhase {
    /// Mean arrival rate during this phase (queries/minute).
    pub rate_per_min: f64,
    /// How long the phase lasts (seconds of trace time).
    pub dur_secs: f64,
}

impl MmppPhase {
    /// Convenience constructor.
    pub fn new(rate_per_min: f64, dur_secs: f64) -> MmppPhase {
        MmppPhase { rate_per_min, dur_secs }
    }
}

/// A fully materialised arrival schedule.
#[derive(Clone, Debug)]
pub struct ArrivalTrace {
    /// Absolute arrival times in seconds, ascending.
    pub times: Vec<f64>,
}

impl ArrivalTrace {
    /// Fixed-rate Poisson trace: `n` arrivals at `beta` queries/minute.
    pub fn poisson_fixed(n: usize, beta: f64, seed: u64) -> ArrivalTrace {
        let mut rng = Pcg64::new(seed);
        let mean_gap = 60.0 / beta.max(1e-9);
        let mut t = 0.0;
        let times = (0..n)
            .map(|_| {
                t += rng.exponential(mean_gap);
                t
            })
            .collect();
        ArrivalTrace { times }
    }

    /// Time-varying trace: beta sweeps `beta_lo..=beta_hi` in integer
    /// steps, one simulated minute per step, cycling until `n` arrivals
    /// are generated (the paper's 10..150 sweep).
    pub fn poisson_sweep(n: usize, beta_lo: u32, beta_hi: u32, seed: u64) -> ArrivalTrace {
        Self::poisson_sweep_scaled(n, beta_lo, beta_hi, 60.0, seed)
    }

    /// Like [`poisson_sweep`] but each beta step lasts `step_secs`
    /// instead of a full minute. With small task counts the plain sweep
    /// never leaves the light-load phase; compressing the step makes `n`
    /// arrivals cover the whole light-to-peak range, preserving the
    /// paper's workload *shape* at reduced scale.
    pub fn poisson_sweep_scaled(
        n: usize,
        beta_lo: u32,
        beta_hi: u32,
        step_secs: f64,
        seed: u64,
    ) -> ArrivalTrace {
        assert!(beta_lo >= 1 && beta_hi >= beta_lo && step_secs > 0.0);
        let mut rng = Pcg64::new(seed);
        let mut times = Vec::with_capacity(n);
        let mut step_start = 0.0;
        let mut beta = beta_lo;
        let mut t = 0.0;
        while times.len() < n {
            let mean_gap = 60.0 / beta as f64;
            // sample arrivals within this beta step
            loop {
                let gap = rng.exponential(mean_gap);
                if t + gap >= step_start + step_secs {
                    t = step_start + step_secs;
                    break;
                }
                t += gap;
                times.push(t);
                if times.len() == n {
                    break;
                }
            }
            step_start += step_secs;
            beta = if beta >= beta_hi { beta_lo } else { beta + 1 };
        }
        ArrivalTrace { times }
    }

    /// Markov-modulated Poisson process: the arrival rate holds each
    /// phase's `rate_per_min` for `dur_secs`, cycling through `phases`
    /// until `n` arrivals are generated — the diurnal/bursty regime of
    /// the scenario gauntlet (a low/high/medium cycle models a day's
    /// traffic curve at compressed scale). Gaps are exponential within
    /// a phase and clamp at the phase boundary, exactly like the beta
    /// sweep's step transitions.
    pub fn mmpp(n: usize, phases: &[MmppPhase], seed: u64) -> ArrivalTrace {
        assert!(!phases.is_empty(), "an MMPP trace needs at least one phase");
        assert!(
            phases.iter().all(|p| p.rate_per_min > 0.0 && p.dur_secs > 0.0),
            "MMPP phases need positive rates and durations"
        );
        let mut rng = Pcg64::new(seed);
        let mut times = Vec::with_capacity(n);
        let mut phase_start = 0.0;
        let mut t = 0.0;
        let mut i = 0usize;
        while times.len() < n {
            let phase = phases[i % phases.len()];
            let mean_gap = 60.0 / phase.rate_per_min;
            let phase_end = phase_start + phase.dur_secs;
            loop {
                let gap = rng.exponential(mean_gap);
                if t + gap >= phase_end {
                    t = phase_end;
                    break;
                }
                t += gap;
                times.push(t);
                if times.len() == n {
                    break;
                }
            }
            phase_start = phase_end;
            i += 1;
        }
        ArrivalTrace { times }
    }

    /// Flash crowd: a steady background Poisson process at
    /// `base_per_min`, plus a burst of `spike_frac` of the `n` arrivals
    /// landing uniformly inside `[spike_start, spike_start +
    /// spike_dur]` — the thundering-herd regime overload shedding and
    /// uncertainty-aware ordering are supposed to survive.
    pub fn flash_crowd(
        n: usize,
        base_per_min: f64,
        spike_start: f64,
        spike_dur: f64,
        spike_frac: f64,
        seed: u64,
    ) -> ArrivalTrace {
        assert!(spike_dur > 0.0 && spike_start >= 0.0, "spike window must be positive");
        let mut rng = Pcg64::new(seed);
        let frac = spike_frac.clamp(0.0, 1.0);
        let n_spike = ((n as f64) * frac).round() as usize;
        let n_base = n.saturating_sub(n_spike);
        let mut times = Vec::with_capacity(n);
        let mean_gap = 60.0 / base_per_min.max(1e-9);
        let mut t = 0.0;
        for _ in 0..n_base {
            t += rng.exponential(mean_gap);
            times.push(t);
        }
        for _ in 0..n_spike {
            times.push(spike_start + rng.f64() * spike_dur);
        }
        times.sort_by(f64::total_cmp);
        ArrivalTrace { times }
    }

    /// Step duration that makes one full `beta_lo..=beta_hi` sweep emit
    /// roughly `n` arrivals.
    pub fn sweep_step_for(n: usize, beta_lo: u32, beta_hi: u32) -> f64 {
        let total_rate: f64 = (beta_lo..=beta_hi).map(|b| b as f64).sum::<f64>() / 60.0;
        (n as f64 / total_rate).max(0.5)
    }

    /// Number of arrivals.
    pub fn len(&self) -> usize {
        self.times.len()
    }

    /// Is the trace empty?
    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Time of the last arrival (0 when empty).
    pub fn duration(&self) -> f64 {
        self.times.last().copied().unwrap_or(0.0)
    }
}

/// A heavy-tailed output-length distribution family. LLM generation
/// lengths are strongly right-skewed; both classical heavy-tail shapes
/// are offered so the gauntlet can stress length-aware scheduling.
#[derive(Clone, Copy, Debug)]
pub enum LengthDist {
    /// `exp(N(mu, sigma))` tokens — moderate skew, finite variance.
    Lognormal {
        /// Mean of the underlying normal (log-tokens).
        mu: f64,
        /// Standard deviation of the underlying normal.
        sigma: f64,
    },
    /// `scale / U^(1/alpha)` tokens — a power-law tail; `alpha <= 2`
    /// has infinite variance (before the clamp).
    Pareto {
        /// Minimum (scale) parameter in tokens.
        scale: f64,
        /// Tail exponent; smaller is heavier.
        alpha: f64,
    },
}

/// A clamped heavy-tailed sampler for per-request lengths (tokens).
/// The clamp keeps samples inside the serving model's output-length
/// band, so a pathological tail draw cannot generate forever.
#[derive(Clone, Copy, Debug)]
pub struct LengthSampler {
    /// The tail shape.
    pub dist: LengthDist,
    /// Minimum length after clamping (tokens).
    pub lo: usize,
    /// Maximum length after clamping (tokens).
    pub hi: usize,
}

impl LengthSampler {
    /// Draw one clamped length. Non-finite draws (possible only from
    /// degenerate parameters) clamp to `hi`.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let raw = match self.dist {
            LengthDist::Lognormal { mu, sigma } => rng.normal(mu, sigma).exp(),
            LengthDist::Pareto { scale, alpha } => {
                // invert the CDF on (0, 1]; guard the u=0 endpoint
                let u = (1.0 - rng.f64()).max(1e-12);
                scale / u.powf(1.0 / alpha.max(1e-9))
            }
        };
        if !raw.is_finite() {
            return self.hi;
        }
        (raw.round() as usize).clamp(self.lo, self.hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_trace_is_sorted_and_sized() {
        let t = ArrivalTrace::poisson_fixed(500, 60.0, 1);
        assert_eq!(t.len(), 500);
        assert!(t.times.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn fixed_trace_rate_approximately_beta() {
        let t = ArrivalTrace::poisson_fixed(5000, 120.0, 2);
        let rate_per_min = 5000.0 / (t.duration() / 60.0);
        assert!((rate_per_min - 120.0).abs() < 12.0, "rate {rate_per_min}");
    }

    #[test]
    fn sweep_trace_accelerates() {
        let t = ArrivalTrace::poisson_sweep(2000, 10, 150, 3);
        assert_eq!(t.len(), 2000);
        assert!(t.times.windows(2).all(|w| w[0] <= w[1]));
        // early minutes (low beta) must be sparser than later ones
        let early = t.times.iter().filter(|&&x| x < 60.0).count();
        let later = t.times.iter().filter(|&&x| (600.0..660.0).contains(&x)).count();
        if later > 0 {
            assert!(later > early, "early {early} later {later}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ArrivalTrace::poisson_sweep(100, 10, 50, 7);
        let b = ArrivalTrace::poisson_sweep(100, 10, 50, 7);
        assert_eq!(a.times, b.times);
    }

    fn diurnal_phases() -> Vec<MmppPhase> {
        vec![
            MmppPhase::new(30.0, 60.0),
            MmppPhase::new(240.0, 60.0),
            MmppPhase::new(90.0, 60.0),
        ]
    }

    /// Satellite property: every generator's arrivals are finite,
    /// non-negative, and non-decreasing.
    #[test]
    fn gauntlet_traces_are_sorted_and_finite() {
        let traces = [
            ArrivalTrace::mmpp(800, &diurnal_phases(), 11),
            ArrivalTrace::flash_crowd(800, 60.0, 5.0, 2.0, 0.5, 12),
        ];
        for t in &traces {
            assert_eq!(t.len(), 800);
            assert!(t.times.iter().all(|x| x.is_finite() && *x >= 0.0));
            assert!(t.times.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    /// Satellite property: seeded runs are bit-reproducible; a
    /// different seed produces a different trace.
    #[test]
    fn gauntlet_traces_deterministic_by_seed() {
        let phases = diurnal_phases();
        let a = ArrivalTrace::mmpp(300, &phases, 42);
        let b = ArrivalTrace::mmpp(300, &phases, 42);
        assert_eq!(a.times, b.times);
        let c = ArrivalTrace::mmpp(300, &phases, 43);
        assert_ne!(a.times, c.times);

        let fa = ArrivalTrace::flash_crowd(300, 60.0, 5.0, 2.0, 0.5, 42);
        let fb = ArrivalTrace::flash_crowd(300, 60.0, 5.0, 2.0, 0.5, 42);
        assert_eq!(fa.times, fb.times);
    }

    /// Satellite property: the MMPP empirical rate inside each phase's
    /// windows lands within tolerance of that phase's configured rate.
    #[test]
    fn mmpp_per_phase_empirical_rate_within_tolerance() {
        let phases = [MmppPhase::new(30.0, 60.0), MmppPhase::new(240.0, 60.0)];
        let t = ArrivalTrace::mmpp(4000, &phases, 5);
        let cycle = 120.0;
        let n_cycles = (t.duration() / cycle).floor() as usize;
        assert!(n_cycles >= 3, "trace too short for a rate check: {n_cycles} cycles");
        // tally arrivals per phase position across all complete cycles
        let mut counts = [0usize; 2];
        for &x in &t.times {
            if x >= n_cycles as f64 * cycle {
                break;
            }
            let in_cycle = x % cycle;
            counts[if in_cycle < 60.0 { 0 } else { 1 }] += 1;
        }
        for (i, phase) in phases.iter().enumerate() {
            let rate = counts[i] as f64 / n_cycles as f64; // arrivals/min (60 s windows)
            let tol = 0.25 * phase.rate_per_min;
            assert!(
                (rate - phase.rate_per_min).abs() < tol,
                "phase {i}: empirical {rate}/min vs configured {}/min",
                phase.rate_per_min
            );
        }
    }

    /// Satellite property: the configured fraction of flash-crowd
    /// arrivals lands inside the spike window.
    #[test]
    fn flash_crowd_spike_mass_inside_window() {
        let (n, frac, start, dur) = (1000usize, 0.4, 8.0, 2.0);
        let t = ArrivalTrace::flash_crowd(n, 60.0, start, dur, frac, 9);
        assert_eq!(t.len(), n);
        let in_window =
            t.times.iter().filter(|&&x| x >= start && x <= start + dur).count();
        // every spike arrival lands inside; background adds a few more
        let spike = (n as f64 * frac).round() as usize;
        assert!(in_window >= spike, "window holds {in_window} < spike mass {spike}");
        // the window is genuinely denser than the background: at 60/min
        // the 2 s window would carry ~2 background arrivals
        assert!(in_window as f64 >= 0.9 * spike as f64 + 2.0);
    }

    /// Satellite property: the heavy-tailed length sampler respects its
    /// clamp for both tail families and actually spreads.
    #[test]
    fn length_sampler_respects_clamp() {
        let samplers = [
            LengthSampler {
                dist: LengthDist::Lognormal { mu: 2.5, sigma: 0.9 },
                lo: 4,
                hi: 96,
            },
            LengthSampler { dist: LengthDist::Pareto { scale: 6.0, alpha: 1.1 }, lo: 4, hi: 96 },
        ];
        for s in &samplers {
            let mut rng = Pcg64::new(77);
            let draws: Vec<usize> = (0..2000).map(|_| s.sample(&mut rng)).collect();
            assert!(draws.iter().all(|&x| (s.lo..=s.hi).contains(&x)));
            let (min, max) = (draws.iter().min().unwrap(), draws.iter().max().unwrap());
            assert!(max > min, "degenerate sampler: all draws {min}");
            // heavy tails must actually hit the clamp ceiling sometimes
            assert!(*max == s.hi, "{:?} never reached hi", s.dist);
        }
        // determinism
        let s = samplers[0];
        let mut a = Pcg64::new(3);
        let mut b = Pcg64::new(3);
        let xa: Vec<usize> = (0..100).map(|_| s.sample(&mut a)).collect();
        let xb: Vec<usize> = (0..100).map(|_| s.sample(&mut b)).collect();
        assert_eq!(xa, xb);
    }
}
