//! Poisson arrival traces (Sec. V-A): inter-arrival times sampled from
//! an exponential distribution whose rate beta (queries/minute) evolves
//! over time — the paper iterates integer beta from 10 to 150, one
//! minute each, covering light-load through high-traffic peaks.

use crate::util::rng::Pcg64;

/// A fully materialised arrival schedule.
#[derive(Clone, Debug)]
pub struct ArrivalTrace {
    /// Absolute arrival times in seconds, ascending.
    pub times: Vec<f64>,
}

impl ArrivalTrace {
    /// Fixed-rate Poisson trace: `n` arrivals at `beta` queries/minute.
    pub fn poisson_fixed(n: usize, beta: f64, seed: u64) -> ArrivalTrace {
        let mut rng = Pcg64::new(seed);
        let mean_gap = 60.0 / beta.max(1e-9);
        let mut t = 0.0;
        let times = (0..n)
            .map(|_| {
                t += rng.exponential(mean_gap);
                t
            })
            .collect();
        ArrivalTrace { times }
    }

    /// Time-varying trace: beta sweeps `beta_lo..=beta_hi` in integer
    /// steps, one simulated minute per step, cycling until `n` arrivals
    /// are generated (the paper's 10..150 sweep).
    pub fn poisson_sweep(n: usize, beta_lo: u32, beta_hi: u32, seed: u64) -> ArrivalTrace {
        Self::poisson_sweep_scaled(n, beta_lo, beta_hi, 60.0, seed)
    }

    /// Like [`poisson_sweep`] but each beta step lasts `step_secs`
    /// instead of a full minute. With small task counts the plain sweep
    /// never leaves the light-load phase; compressing the step makes `n`
    /// arrivals cover the whole light-to-peak range, preserving the
    /// paper's workload *shape* at reduced scale.
    pub fn poisson_sweep_scaled(
        n: usize,
        beta_lo: u32,
        beta_hi: u32,
        step_secs: f64,
        seed: u64,
    ) -> ArrivalTrace {
        assert!(beta_lo >= 1 && beta_hi >= beta_lo && step_secs > 0.0);
        let mut rng = Pcg64::new(seed);
        let mut times = Vec::with_capacity(n);
        let mut step_start = 0.0;
        let mut beta = beta_lo;
        let mut t = 0.0;
        while times.len() < n {
            let mean_gap = 60.0 / beta as f64;
            // sample arrivals within this beta step
            loop {
                let gap = rng.exponential(mean_gap);
                if t + gap >= step_start + step_secs {
                    t = step_start + step_secs;
                    break;
                }
                t += gap;
                times.push(t);
                if times.len() == n {
                    break;
                }
            }
            step_start += step_secs;
            beta = if beta >= beta_hi { beta_lo } else { beta + 1 };
        }
        ArrivalTrace { times }
    }

    /// Step duration that makes one full `beta_lo..=beta_hi` sweep emit
    /// roughly `n` arrivals.
    pub fn sweep_step_for(n: usize, beta_lo: u32, beta_hi: u32) -> f64 {
        let total_rate: f64 = (beta_lo..=beta_hi).map(|b| b as f64).sum::<f64>() / 60.0;
        (n as f64 / total_rate).max(0.5)
    }

    /// Number of arrivals.
    pub fn len(&self) -> usize {
        self.times.len()
    }

    /// Is the trace empty?
    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Time of the last arrival (0 when empty).
    pub fn duration(&self) -> f64 {
        self.times.last().copied().unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_trace_is_sorted_and_sized() {
        let t = ArrivalTrace::poisson_fixed(500, 60.0, 1);
        assert_eq!(t.len(), 500);
        assert!(t.times.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn fixed_trace_rate_approximately_beta() {
        let t = ArrivalTrace::poisson_fixed(5000, 120.0, 2);
        let rate_per_min = 5000.0 / (t.duration() / 60.0);
        assert!((rate_per_min - 120.0).abs() < 12.0, "rate {rate_per_min}");
    }

    #[test]
    fn sweep_trace_accelerates() {
        let t = ArrivalTrace::poisson_sweep(2000, 10, 150, 3);
        assert_eq!(t.len(), 2000);
        assert!(t.times.windows(2).all(|w| w[0] <= w[1]));
        // early minutes (low beta) must be sparser than later ones
        let early = t.times.iter().filter(|&&x| x < 60.0).count();
        let later = t.times.iter().filter(|&&x| (600.0..660.0).contains(&x)).count();
        if later > 0 {
            assert!(later > early, "early {early} later {later}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ArrivalTrace::poisson_sweep(100, 10, 50, 7);
        let b = ArrivalTrace::poisson_sweep(100, 10, 50, 7);
        assert_eq!(a.times, b.times);
    }
}
