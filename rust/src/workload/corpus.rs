//! Corpus records produced by the python AOT build
//! (`artifacts/corpus/*.jsonl`) — the synthetic stand-in for the paper's
//! four HuggingFace dialogue datasets (see DESIGN.md §Substitutions).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::json::{read_jsonl, Json};

/// One utterance with its ground-truth length-oracle data.
#[derive(Clone, Debug)]
pub struct WorkItem {
    /// Raw utterance text.
    pub text: String,
    /// Primary uncertainty type the generator assigned.
    pub utype: String,
    /// Input length in tokens.
    pub input_len: usize,
    /// Cross-LM base output length.
    pub base_len: usize,
    /// Per-LM actual output length (the length oracle).
    pub lens: BTreeMap<String, usize>,
    /// RULEGEN features computed at build time (six scores + input len).
    pub features: Vec<f64>,
}

impl WorkItem {
    /// Parse one corpus JSONL record.
    pub fn from_json(v: &Json) -> Result<WorkItem> {
        let mut lens = BTreeMap::new();
        for (model, len) in v.need_obj("lens")? {
            lens.insert(
                model.clone(),
                len.as_f64().ok_or_else(|| anyhow!("bad length"))? as usize,
            );
        }
        let features = v
            .need_arr("features")?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad feature")))
            .collect::<Result<Vec<_>>>()?;
        Ok(WorkItem {
            text: v.need_str("text")?.to_string(),
            utype: v.need_str("type")?.to_string(),
            input_len: v.need_f64("input_len")? as usize,
            base_len: v.need_f64("base_len")? as usize,
            lens,
            features,
        })
    }

    /// The length oracle's output length on one LM.
    pub fn len_for(&self, model: &str) -> usize {
        self.lens.get(model).copied().unwrap_or(self.base_len)
    }

    /// Mean output length across all LMs (Fig. 2's y-axis).
    pub fn mean_len(&self) -> f64 {
        if self.lens.is_empty() {
            return self.base_len as f64;
        }
        self.lens.values().map(|&l| l as f64).sum::<f64>() / self.lens.len() as f64
    }
}

/// Load one corpus JSONL file.
pub fn load(path: &Path) -> Result<Vec<WorkItem>> {
    read_jsonl(path)?.iter().map(WorkItem::from_json).collect()
}

/// Load and concatenate several corpus files.
pub fn load_many<'a>(paths: impl IntoIterator<Item = &'a std::path::PathBuf>) -> Result<Vec<WorkItem>> {
    let mut out = Vec::new();
    for p in paths {
        out.extend(load(p)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_record() {
        let line = r#"{"text":"hi there","type":"plain","input_len":2,"base_len":10,
            "lens":{"t5":9,"bart":8},"features":[0,0,0,0,0,0,2]}"#;
        let v = Json::parse(line).unwrap();
        let item = WorkItem::from_json(&v).unwrap();
        assert_eq!(item.text, "hi there");
        assert_eq!(item.len_for("t5"), 9);
        assert_eq!(item.len_for("unknown"), 10);
        assert!((item.mean_len() - 8.5).abs() < 1e-9);
        assert_eq!(item.features.len(), 7);
    }

    #[test]
    fn rejects_missing_fields() {
        let v = Json::parse(r#"{"text":"x"}"#).unwrap();
        assert!(WorkItem::from_json(&v).is_err());
    }
}
