//! Workload engine: corpus loading, Poisson arrival traces (Sec. V-A
//! "Workload setup"), uncertainty-variance subsets (Sec. V-B), and the
//! adversarial "malicious task" generator (Sec. V-G).

pub mod corpus;
pub mod malicious;
pub mod subsets;
pub mod synth;
pub mod tasks;
pub mod trace;

pub use corpus::WorkItem;
pub use synth::SynthGenerator;
pub use tasks::TaskFactory;
pub use trace::ArrivalTrace;
