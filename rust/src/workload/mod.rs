//! Workload engine: corpus loading, arrival traces (Sec. V-A "Workload
//! setup" Poisson plus the gauntlet's MMPP / flash-crowd / heavy-tailed
//! generators), SLO-class assignment, uncertainty-variance subsets
//! (Sec. V-B), and the adversarial "malicious task" generator (Sec. V-G).

pub mod corpus;
pub mod malicious;
pub mod subsets;
pub mod synth;
pub mod tasks;
pub mod trace;

pub use corpus::WorkItem;
pub use synth::SynthGenerator;
pub use tasks::{SloMix, TaskFactory};
pub use trace::{ArrivalTrace, LengthDist, LengthSampler, MmppPhase};
