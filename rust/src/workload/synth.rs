//! Rust-native synthetic utterance generator.
//!
//! Mirrors `python/compile/corpus.py`'s templates over the shared
//! lexicon export, so the serving demos (TCP front-end, infinite
//! workloads) can fabricate fresh inputs at runtime without touching the
//! corpus files. Statistical twin of the python generator — same pools,
//! same template shapes — though not bit-identical (different RNG).

use std::sync::Arc;

use crate::config::manifest::LengthModel;
use crate::textgen::Lexicon;
use crate::util::rng::Pcg64;

use super::corpus::WorkItem;

/// Static pools shared with the python generator for words the lexicon
/// export does not carry as separate lists.
const PLAIN_SUBJECTS: [&str; 6] = ["i", "you", "we", "they", "he", "she"];
const PLAIN_VERBS: [&str; 6] = ["like", "love", "enjoy", "want", "have", "prefer"];
const PLAIN_OBJECTS: [&str; 10] = [
    "pizza", "coffee", "books", "movies", "music", "dogs", "cats", "games", "tea", "sports",
];
const CONCRETE_NOUNS: [&str; 8] =
    ["boy", "girl", "dog", "cat", "telescope", "book", "camera", "umbrella"];
const PLACES: [&str; 6] = ["park", "garden", "street", "school", "market", "beach"];
const COUNTRY_TOPICS: [&str; 4] =
    ["developing countries", "modern cities", "rural areas", "small towns"];
const COMPARE_PAIRS: [(&str, &str); 4] =
    [("cats", "dogs"), ("books", "movies"), ("coffee", "tea"), ("cities", "villages")];
const COMPARE_ASPECTS: [&str; 6] = ["behavior", "diet", "cost", "culture", "history", "size"];

/// Synthetic-utterance generator (mirror of `compile/corpus.py`'s
/// construction, driven by the shared lexicon).
pub struct SynthGenerator {
    lexicon: Arc<Lexicon>,
    length_model: LengthModel,
    rng: Pcg64,
}

impl SynthGenerator {
    /// Seeded generator over the given lexicon and length model.
    pub fn new(lexicon: Arc<Lexicon>, length_model: LengthModel, seed: u64) -> SynthGenerator {
        SynthGenerator { lexicon, length_model, rng: Pcg64::new(seed ^ 0x517417) }
    }

    fn pick<'a>(&mut self, pool: &'a [String]) -> &'a str {
        pool[self.rng.range_usize(0, pool.len())].as_str()
    }

    fn pick_set(&mut self, set: &std::collections::HashSet<String>) -> String {
        let items: Vec<&String> = set.iter().collect();
        items[self.rng.range_usize(0, items.len())].clone()
    }

    /// Generate an utterance of the given uncertainty type.
    pub fn utterance(&mut self, utype: &str) -> String {
        let vague: Vec<String> = {
            let mut v: Vec<String> = self.lexicon.vague_topics.iter().cloned().collect();
            v.sort(); // deterministic order for the seeded picks
            v
        };
        match utype {
            "structural" => {
                let subj = *self.rng.choice(&PLAIN_SUBJECTS);
                let n1 = *self.rng.choice(&CONCRETE_NOUNS);
                let place = *self.rng.choice(&PLACES);
                let n2 = *self.rng.choice(&CONCRETE_NOUNS);
                format!("{subj} saw a {n1} in the {place} with a {n2} .")
            }
            "syntactic" => {
                let mut nv: Vec<String> = self.lexicon.nv_ambiguous.iter().cloned().collect();
                nv.sort();
                let w1 = self.pick(&nv).to_string();
                let w2 = self.pick(&nv).to_string();
                format!("rice {w1} {w2} fast .")
            }
            "semantic" => {
                let mut homonyms: Vec<String> = self.lexicon.homonyms.keys().cloned().collect();
                homonyms.sort();
                let h = self.pick(&homonyms).to_string();
                format!("what's the best way to deal with {h} ?")
            }
            "vague" => {
                let t1 = self.pick(&vague).to_string();
                let t2 = self.pick(&vague).to_string();
                format!("tell me about the {t1} of {t2} .")
            }
            "open" => {
                let marker = self.pick_set(&self.lexicon.open_markers.clone());
                let marker2 = self.pick_set(&self.lexicon.open_markers.clone());
                let wher = *self.rng.choice(&COUNTRY_TOPICS);
                format!("what are the {marker} and {marker2} of poverty in {wher} ?")
            }
            "multipart" => {
                let (a, b) = *self.rng.choice(&COMPARE_PAIRS);
                let a1 = *self.rng.choice(&COMPARE_ASPECTS);
                let a2 = *self.rng.choice(&COMPARE_ASPECTS);
                let a3 = *self.rng.choice(&COMPARE_ASPECTS);
                format!("how do {a} and {b} differ in {a1} , {a2} , and {a3} ?")
            }
            _ => {
                let subj = *self.rng.choice(&PLAIN_SUBJECTS);
                let verb = *self.rng.choice(&PLAIN_VERBS);
                let obj = *self.rng.choice(&PLAIN_OBJECTS);
                format!("{subj} {verb} {obj} .")
            }
        }
    }

    /// Generate a full work item: text + oracle lengths drawn from the
    /// manifest's per-type length model (mirror of corpus.base_length).
    pub fn work_item(&mut self, utype: &str, model_names: &[String]) -> WorkItem {
        let text = self.utterance(utype);
        let input_len = crate::textgen::tokenize(&text).len();
        let (mean, std) = self
            .length_model
            .per_type
            .get(utype)
            .copied()
            .unwrap_or((16.0, 4.0));
        let raw = self.rng.normal(mean, std) + self.length_model.input_coef * input_len as f64;
        let base = raw.round().clamp(4.0, 96.0) as usize;
        let mut lens = std::collections::BTreeMap::new();
        for name in model_names {
            let noisy = base as f64 + self.rng.normal(0.0, self.length_model.noise_std);
            lens.insert(name.clone(), noisy.round().clamp(4.0, 96.0) as usize);
        }
        WorkItem {
            text,
            utype: utype.to_string(),
            input_len,
            base_len: base,
            lens,
            features: vec![], // runtime path rescoring computes these
        }
    }

    /// An endless stream cycling through the type mixture.
    pub fn stream(&mut self, types: &[String], n: usize, model_names: &[String]) -> Vec<WorkItem> {
        (0..n)
            .map(|_| {
                let utype = types[self.rng.range_usize(0, types.len())].clone();
                self.work_item(&utype, model_names)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end in rust/tests/sim_scenarios.rs (needs the
    // lexicon artifact); pure-logic pieces are covered there.
}
