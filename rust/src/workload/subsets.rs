//! Uncertainty-variance subsets (Sec. V-B): the paper evaluates every
//! policy on task subsets with *small*, *normal*, and *large* variance
//! of uncertainty scores — uncertainty-aware scheduling only pays off
//! when execution times actually vary.

use crate::util::rng::Pcg64;

use super::corpus::WorkItem;

/// Requested uncertainty-score spread of a task subset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variance {
    /// Tight spread around the median score.
    Small,
    /// The corpus's natural spread.
    Normal,
    /// Tails emphasised (high-variance workload).
    Large,
}

impl Variance {
    /// All three variances, in the paper's order.
    pub const ALL: [Variance; 3] = [Variance::Small, Variance::Normal, Variance::Large];

    /// Display label, as the paper's tables print it.
    pub fn label(&self) -> &'static str {
        match self {
            Variance::Small => "Small",
            Variance::Normal => "Normal",
            Variance::Large => "Large",
        }
    }
}

/// Draw `n` items with the requested uncertainty-score spread.
///
/// `scores[i]` is the uncertainty score of `items[i]` (any monotone
/// execution-time proxy works). Selection:
/// - Small: the middle band (40th-60th percentile) — near-uniform work.
/// - Normal: the 15th-85th percentile band — the natural mix.
/// - Large: stratified across the full range with oversampled tails.
pub fn select(
    items: &[WorkItem],
    scores: &[f64],
    variance: Variance,
    n: usize,
    seed: u64,
) -> Vec<WorkItem> {
    assert_eq!(items.len(), scores.len());
    assert!(!items.is_empty());
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());

    let mut rng = Pcg64::new(seed ^ 0x5b5e7);
    let pick_band = |rng: &mut Pcg64, lo: f64, hi: f64| -> usize {
        let lo_i = ((items.len() as f64) * lo) as usize;
        let hi_i = (((items.len() as f64) * hi) as usize).max(lo_i + 1).min(items.len());
        order[rng.range_usize(lo_i, hi_i)]
    };

    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let idx = match variance {
            Variance::Small => pick_band(&mut rng, 0.40, 0.60),
            Variance::Normal => pick_band(&mut rng, 0.15, 0.85),
            Variance::Large => {
                // thirds: low tail, middle, high tail
                match i % 3 {
                    0 => pick_band(&mut rng, 0.0, 0.15),
                    1 => pick_band(&mut rng, 0.15, 0.85),
                    _ => pick_band(&mut rng, 0.85, 1.0),
                }
            }
        };
        out.push(items[idx].clone());
    }
    rng.shuffle(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn items_with_lens(lens: &[usize]) -> Vec<WorkItem> {
        lens.iter()
            .map(|&l| WorkItem {
                text: String::new(),
                utype: "plain".into(),
                input_len: 5,
                base_len: l,
                lens: BTreeMap::new(),
                features: vec![0.0; 7],
            })
            .collect()
    }

    fn variance_of(items: &[WorkItem]) -> f64 {
        let xs: Vec<f64> = items.iter().map(|i| i.base_len as f64).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
    }

    #[test]
    fn variance_ordering_holds() {
        let lens: Vec<usize> = (4..=96).collect();
        let items = items_with_lens(&lens);
        let scores: Vec<f64> = items.iter().map(|i| i.base_len as f64).collect();
        let small = select(&items, &scores, Variance::Small, 300, 1);
        let normal = select(&items, &scores, Variance::Normal, 300, 1);
        let large = select(&items, &scores, Variance::Large, 300, 1);
        let (vs, vn, vl) = (variance_of(&small), variance_of(&normal), variance_of(&large));
        assert!(vs < vn, "small {vs} !< normal {vn}");
        assert!(vn < vl, "normal {vn} !< large {vl}");
    }

    #[test]
    fn returns_requested_count() {
        let items = items_with_lens(&[1, 2, 3]);
        let scores = vec![1.0, 2.0, 3.0];
        assert_eq!(select(&items, &scores, Variance::Large, 50, 0).len(), 50);
    }

    #[test]
    fn deterministic_by_seed() {
        let lens: Vec<usize> = (4..=60).collect();
        let items = items_with_lens(&lens);
        let scores: Vec<f64> = items.iter().map(|i| i.base_len as f64).collect();
        let a = select(&items, &scores, Variance::Normal, 40, 9);
        let b = select(&items, &scores, Variance::Normal, 40, 9);
        assert_eq!(
            a.iter().map(|i| i.base_len).collect::<Vec<_>>(),
            b.iter().map(|i| i.base_len).collect::<Vec<_>>()
        );
    }
}
