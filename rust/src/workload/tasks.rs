//! Task factory: combine corpus items + an arrival trace into scheduler
//! tasks — computing the uncertainty score u_J (Eq. 1) and the priority
//! point d_J = r_J + base + phi_f * |J| (Sec. IV-B).

use anyhow::Result;

use crate::config::ModelEntry;
use crate::scheduler::{SloClass, Task};
use crate::textgen::ScoreScratch;
use crate::uncertainty::Estimator;
use crate::util::rng::Pcg64;

use super::corpus::WorkItem;
use super::malicious;
use super::trace::ArrivalTrace;

/// Turns corpus items + an arrival trace into scored, deadlined tasks.
pub struct TaskFactory {
    estimator: Estimator,
    /// Reused scoring buffers: rescoring goes through the interned
    /// fast path, so batch task building stops allocating per item
    /// once the buffers reach steady state.
    scratch: ScoreScratch,
    /// Base relative deadline added to phi_f * |J| (seconds). The paper's
    /// d = phi|J| alone makes most slacks negative under our calibrated
    /// latencies; a constant base keeps Eq. 3 informative (DESIGN.md).
    pub deadline_base: f64,
}

impl TaskFactory {
    /// Factory over the given estimator and relative-deadline base.
    pub fn new(estimator: Estimator, deadline_base: f64) -> TaskFactory {
        TaskFactory { estimator, scratch: ScoreScratch::new(), deadline_base }
    }

    /// Build one task with a user-specified deadline t_J (Sec. IV-B:
    /// healthcare-style requests carry explicit deadlines, which replace
    /// the derived priority point).
    pub fn build_with_deadline(
        &mut self,
        id: u64,
        item: &WorkItem,
        arrival: f64,
        model: &ModelEntry,
        deadline: f64,
    ) -> Result<Task> {
        let mut task = self.build(id, item, arrival, model, false)?;
        task.priority_point = arrival + deadline;
        Ok(task)
    }

    /// Build one task. `rescore = true` recomputes RULEGEN features from
    /// the text (the real serving path; required for crafted items whose
    /// stored features are stale); otherwise the build-time features are
    /// reused and only the regressor runs.
    pub fn build(
        &mut self,
        id: u64,
        item: &WorkItem,
        arrival: f64,
        model: &ModelEntry,
        rescore: bool,
    ) -> Result<Task> {
        let (uncertainty, input_len) = if rescore || item.features.is_empty() {
            let (score, feats) =
                self.estimator.score_with_features_scratch(&item.text, &mut self.scratch)?;
            (score, feats[feats.len() - 1] as usize)
        } else {
            let score = self.estimator.score_features(&item.features)?;
            (score, item.input_len)
        };
        let priority_point = arrival + self.deadline_base + model.phi * input_len as f64;
        Ok(Task {
            id,
            text: item.text.clone(),
            prompt: Vec::new(),
            arrival,
            priority_point,
            uncertainty,
            true_len: item.len_for(&model.name),
            input_len,
            utype: item.utype.clone(),
            malicious: malicious::is_crafted(item),
            deferrals: 0,
            slo: SloClass::Standard,
        })
    }

    /// Zip items onto a trace (item i arrives at times[i]; items cycle if
    /// the trace is longer).
    pub fn build_all(
        &mut self,
        items: &[WorkItem],
        trace: &ArrivalTrace,
        model: &ModelEntry,
        rescore: bool,
    ) -> Result<Vec<Task>> {
        assert!(!items.is_empty());
        trace
            .times
            .iter()
            .enumerate()
            .map(|(i, &t)| self.build(i as u64, &items[i % items.len()], t, model, rescore))
            .collect()
    }

    /// The estimator tasks are scored with.
    pub fn estimator(&self) -> &Estimator {
        &self.estimator
    }
}

/// Seeded two-class SLO assigner: a fraction of tasks becomes
/// [`SloClass::Interactive`] (tight deadline), the rest
/// [`SloClass::Batch`] (loose deadline). Assignment rewrites each
/// task's priority point to `arrival + class deadline`, which is the
/// entire scheduler interface of an SLO class — UP priority (Eq. 3)
/// consumes priority points, so classed traffic needs no new
/// scheduling code and classless runs are untouched.
#[derive(Clone, Copy, Debug)]
pub struct SloMix {
    /// Fraction of tasks assigned the interactive class (clamped to
    /// [0, 1] by the `rng.f64() < frac` draw).
    pub interactive_frac: f64,
    /// Relative deadline (seconds after arrival) for interactive tasks.
    pub interactive_deadline: f64,
    /// Relative deadline (seconds after arrival) for batch tasks.
    pub batch_deadline: f64,
}

impl SloMix {
    /// Assign classes task-by-task with a fresh PCG64 stream: the same
    /// `(tasks, seed)` always yields the same classes and deadlines.
    pub fn assign(&self, tasks: &mut [Task], seed: u64) {
        let mut rng = Pcg64::new(seed);
        for t in tasks.iter_mut() {
            let (slo, deadline) = if rng.f64() < self.interactive_frac {
                (SloClass::Interactive, self.interactive_deadline)
            } else {
                (SloClass::Batch, self.batch_deadline)
            };
            t.slo = slo;
            t.priority_point = t.arrival + deadline;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::task::test_task;

    #[test]
    fn slo_mix_is_deterministic_and_rewrites_deadlines() {
        let mix = SloMix {
            interactive_frac: 0.5,
            interactive_deadline: 2.0,
            batch_deadline: 60.0,
        };
        let mk = || (0..64).map(|i| test_task(i, i as f64 * 0.1, 0.0, 10.0)).collect::<Vec<_>>();
        let mut a = mk();
        let mut b = mk();
        mix.assign(&mut a, 9);
        mix.assign(&mut b, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.slo, y.slo);
            assert_eq!(x.priority_point, y.priority_point);
            let expect = match x.slo {
                SloClass::Interactive => x.arrival + 2.0,
                SloClass::Batch => x.arrival + 60.0,
                SloClass::Standard => panic!("mix never assigns Standard"),
            };
            assert_eq!(x.priority_point, expect);
        }
        // both classes actually occur at frac = 0.5 over 64 draws
        assert!(a.iter().any(|t| t.slo == SloClass::Interactive));
        assert!(a.iter().any(|t| t.slo == SloClass::Batch));
        // a different seed produces a different assignment
        let mut c = mk();
        mix.assign(&mut c, 10);
        assert!(a.iter().zip(&c).any(|(x, y)| x.slo != y.slo));
    }
}
