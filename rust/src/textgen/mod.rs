//! Text processing: tokenizer, vocabulary, and PoS-lite tagger.
//!
//! Exact rust mirror of `python/compile/textproc.py` (the build path).
//! The contract is enforced by golden-file tests against
//! `artifacts/goldens/textproc_golden.jsonl`: any divergence in
//! tokenisation, tagging, or vocabulary numbering is a test failure, not
//! a silent drift.

pub mod intern;
pub mod lexicon;
pub mod pos;
pub mod tokenizer;
pub mod vocab;

pub use intern::{ScoreTable, WordInfo};
pub use lexicon::{Lexicon, Tag};
pub use tokenizer::{tokenize, tokenize_into, ScoreScratch};
pub use vocab::Vocab;
