//! Whitespace + punctuation tokenizer (exact mirror of
//! `textproc.tokenize`).

/// The punctuation characters split into their own tokens.
/// Must stay identical to python's `_PUNCT = ".,!?;:\"()"`.
pub const PUNCT: &[char] = &['.', ',', '!', '?', ';', ':', '"', '(', ')'];

/// Is this one of the punctuation characters that split off?
pub fn is_punct(c: char) -> bool {
    PUNCT.contains(&c)
}

/// Lowercase, split on whitespace, split off leading/trailing punctuation
/// as separate tokens (trailing punctuation emitted in string order).
pub fn tokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    for raw in text.to_lowercase().split_whitespace() {
        let chars: Vec<char> = raw.chars().collect();
        let mut start = 0;
        while start < chars.len() && is_punct(chars[start]) {
            out.push(chars[start].to_string());
            start += 1;
        }
        let mut end = chars.len();
        let mut trailing = Vec::new();
        while end > start && is_punct(chars[end - 1]) {
            trailing.push(chars[end - 1].to_string());
            end -= 1;
        }
        if end > start {
            out.push(chars[start..end].iter().collect());
        }
        out.extend(trailing.into_iter().rev());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic() {
        assert_eq!(tokenize("I love pizza."), vec!["i", "love", "pizza", "."]);
        assert_eq!(tokenize("what?  really!"), vec!["what", "?", "really", "!"]);
        assert!(tokenize("").is_empty());
    }

    #[test]
    fn punctuation_order() {
        assert_eq!(tokenize("ok?!"), vec!["ok", "?", "!"]);
        assert_eq!(tokenize("\"quoted\""), vec!["\"", "quoted", "\""]);
    }

    #[test]
    fn keeps_apostrophes() {
        assert_eq!(tokenize("what's up"), vec!["what's", "up"]);
    }

    #[test]
    fn all_punct_token() {
        assert_eq!(tokenize("..."), vec![".", ".", "."]);
    }
}
