//! Whitespace + punctuation tokenizer (exact mirror of
//! `textproc.tokenize`).

/// The punctuation characters split into their own tokens.
/// Must stay identical to python's `_PUNCT = ".,!?;:\"()"`.
pub const PUNCT: &[char] = &['.', ',', '!', '?', ';', ':', '"', '(', ')'];

/// Is this one of the punctuation characters that split off?
pub fn is_punct(c: char) -> bool {
    PUNCT.contains(&c)
}

/// Byte-level [`is_punct`]: every split-off punctuation character is a
/// single ASCII byte, and UTF-8 continuation bytes are >= 0x80, so a
/// byte test can never false-match inside a multi-byte character.
#[inline]
pub fn is_punct_byte(b: u8) -> bool {
    matches!(b, b'.' | b',' | b'!' | b'?' | b';' | b':' | b'"' | b'(' | b')')
}

/// Reusable buffers for the allocation-free scoring fast path: the
/// lowercased text, the token byte-spans into it, the per-token
/// interned word ids, and the regressor's ping-pong activation buffers.
///
/// Contract: a scratch is plumbing, not state — every fast-path entry
/// point ([`tokenize_into`], `Estimator::score_scratch` and friends)
/// fully resets the parts it uses, so one scratch can be reused across
/// arbitrary texts (that reuse is the point: after a few calls the
/// buffers reach steady-state capacity and scoring stops allocating).
/// Not `Sync`/shared — keep one per worker (e.g. per connection).
#[derive(Debug, Default)]
pub struct ScoreScratch {
    /// Lowercased copy of the text being scored.
    pub(crate) lower: String,
    /// Token byte-spans `(start, end)` into `lower`, in token order.
    pub(crate) spans: Vec<(usize, usize)>,
    /// Interned word id per token (`intern::NO_WORD` when unknown).
    pub(crate) ids: Vec<u32>,
    /// Regressor activation ping buffer.
    pub(crate) reg_a: Vec<f32>,
    /// Regressor activation pong buffer.
    pub(crate) reg_b: Vec<f32>,
}

impl ScoreScratch {
    /// A fresh scratch with empty buffers (they grow to steady state
    /// over the first few scoring calls).
    pub fn new() -> ScoreScratch {
        ScoreScratch::default()
    }

    /// Number of tokens produced by the last [`tokenize_into`] call.
    pub fn token_count(&self) -> usize {
        self.spans.len()
    }

    /// The tokens of the last [`tokenize_into`] call, as slices of the
    /// internal lowercase buffer.
    pub fn tokens(&self) -> impl Iterator<Item = &str> {
        self.spans.iter().map(|&(s, e)| &self.lower[s..e])
    }
}

/// Lowercase `text` into `buf` (cleared first) without allocating in
/// the common cases, producing byte-identical output to
/// `str::to_lowercase`:
///
/// - pure-ASCII text: bulk copy + in-place ASCII lowercasing;
/// - non-ASCII without 'Σ' (U+03A3): per-char `char::to_lowercase`,
///   which matches `str::to_lowercase` for every char except the
///   context-sensitive capital sigma (and handles multi-char
///   expansions like 'İ' -> "i\u{307}");
/// - text containing 'Σ': fall back to `str::to_lowercase` for its
///   final-sigma handling — the one documented transient allocation.
pub fn lowercase_into(text: &str, buf: &mut String) {
    buf.clear();
    if text.is_ascii() {
        buf.push_str(text);
        buf.make_ascii_lowercase();
    } else if !text.contains('\u{3a3}') {
        for c in text.chars() {
            for lc in c.to_lowercase() {
                buf.push(lc);
            }
        }
    } else {
        buf.push_str(&text.to_lowercase());
    }
}

/// [`tokenize`] into reusable buffers: lowercases `text` into the
/// scratch and records each token as a byte-span of that buffer
/// (no per-token `String`s). Token-for-token identical to
/// [`tokenize`] — asserted by the equivalence tests below and the
/// fast-path property suite.
pub fn tokenize_into(text: &str, scratch: &mut ScoreScratch) {
    scratch.spans.clear();
    // Split borrow: lowercase into a temporarily-moved buffer so the
    // span pushes below can borrow `scratch` mutably.
    let mut lower = std::mem::take(&mut scratch.lower);
    lowercase_into(text, &mut lower);

    // Mirror of `split_whitespace` + per-word punctuation stripping,
    // operating on byte spans of the lowercased buffer. All split-off
    // punctuation is ASCII, so byte tests are exact (see
    // [`is_punct_byte`]).
    let bytes = lower.as_bytes();
    let mut word_start = None;
    for (i, c) in lower.char_indices() {
        if c.is_whitespace() {
            if let Some(start) = word_start.take() {
                push_word_spans(bytes, start, i, &mut scratch.spans);
            }
        } else if word_start.is_none() {
            word_start = Some(i);
        }
    }
    if let Some(start) = word_start {
        push_word_spans(bytes, start, lower.len(), &mut scratch.spans);
    }
    scratch.lower = lower;
}

/// Split one whitespace-delimited word `[start, end)` into its token
/// spans: leading punctuation bytes (each its own token), the core, and
/// trailing punctuation bytes in string order — exactly the order
/// [`tokenize`] emits.
fn push_word_spans(
    bytes: &[u8],
    mut start: usize,
    end: usize,
    spans: &mut Vec<(usize, usize)>,
) {
    while start < end && is_punct_byte(bytes[start]) {
        spans.push((start, start + 1));
        start += 1;
    }
    let mut core_end = end;
    while core_end > start && is_punct_byte(bytes[core_end - 1]) {
        core_end -= 1;
    }
    if core_end > start {
        spans.push((start, core_end));
    }
    for i in core_end..end {
        spans.push((i, i + 1));
    }
}

/// Lowercase, split on whitespace, split off leading/trailing punctuation
/// as separate tokens (trailing punctuation emitted in string order).
pub fn tokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    for raw in text.to_lowercase().split_whitespace() {
        let chars: Vec<char> = raw.chars().collect();
        let mut start = 0;
        while start < chars.len() && is_punct(chars[start]) {
            out.push(chars[start].to_string());
            start += 1;
        }
        let mut end = chars.len();
        let mut trailing = Vec::new();
        while end > start && is_punct(chars[end - 1]) {
            trailing.push(chars[end - 1].to_string());
            end -= 1;
        }
        if end > start {
            out.push(chars[start..end].iter().collect());
        }
        out.extend(trailing.into_iter().rev());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic() {
        assert_eq!(tokenize("I love pizza."), vec!["i", "love", "pizza", "."]);
        assert_eq!(tokenize("what?  really!"), vec!["what", "?", "really", "!"]);
        assert!(tokenize("").is_empty());
    }

    #[test]
    fn punctuation_order() {
        assert_eq!(tokenize("ok?!"), vec!["ok", "?", "!"]);
        assert_eq!(tokenize("\"quoted\""), vec!["\"", "quoted", "\""]);
    }

    #[test]
    fn keeps_apostrophes() {
        assert_eq!(tokenize("what's up"), vec!["what's", "up"]);
    }

    #[test]
    fn all_punct_token() {
        assert_eq!(tokenize("..."), vec![".", ".", "."]);
    }

    fn assert_into_matches(text: &str) {
        let mut scratch = ScoreScratch::new();
        tokenize_into(text, &mut scratch);
        let got: Vec<&str> = scratch.tokens().collect();
        let want = tokenize(text);
        assert_eq!(got, want, "tokenize_into diverged on {text:?}");
    }

    #[test]
    fn tokenize_into_matches_tokenize() {
        for text in [
            "",
            "   ",
            "I love pizza.",
            "what?  really!",
            "ok?!",
            "\"quoted\"",
            "what's up",
            "...",
            "a.b,c!d",
            "  leading and trailing  ",
            "tabs\tand\nnewlines\r\nmixed",
        ] {
            assert_into_matches(text);
        }
    }

    #[test]
    fn tokenize_into_matches_tokenize_unicode() {
        for text in [
            "Καλημέρα ΣΟΦΙΑ",     // capital sigma mid-word
            "ΟΔΥΣΣΕΥΣ.",          // final sigma before punctuation
            "İstanbul DİYARBAKIR", // 'İ' lowercases to two chars
            "ĞÜZEL, naïve!",
            "e\u{301}toile (cafe\u{301})", // combining accents
            "ß STRASSE",
            "中文 没有 空格?",
        ] {
            assert_into_matches(text);
        }
    }

    #[test]
    fn lowercase_into_matches_std() {
        let mut buf = String::new();
        for text in ["", "ASCII only.", "İΣΣΑ ΣΟΦΟΣ", "Weiß", "ΣΣ", "aΣ"] {
            lowercase_into(text, &mut buf);
            assert_eq!(buf, text.to_lowercase(), "diverged on {text:?}");
        }
    }

    #[test]
    fn scratch_reuse_across_texts() {
        let mut scratch = ScoreScratch::new();
        tokenize_into("a much longer first text, with punctuation!", &mut scratch);
        tokenize_into("short", &mut scratch);
        assert_eq!(scratch.tokens().collect::<Vec<_>>(), vec!["short"]);
        assert_eq!(scratch.token_count(), 1);
        tokenize_into("", &mut scratch);
        assert_eq!(scratch.token_count(), 0);
    }
}
