//! PoS-lite tagger (exact mirror of `textproc.pos_tag`): lexicon lookup,
//! then suffix heuristics, else NOUN; punctuation tags PUNCT.

use super::lexicon::{Lexicon, Tag};
use super::tokenizer::is_punct;

/// Tag each token: lexicon lookup, then suffix heuristics, else NOUN.
pub fn pos_tag(lex: &Lexicon, tokens: &[String]) -> Vec<Tag> {
    tokens
        .iter()
        .map(|tok| {
            if tok.chars().next().map(is_punct).unwrap_or(false) {
                return Tag::Punct;
            }
            if let Some(tag) = lex.pos_lexicon.get(tok.as_str()) {
                return *tag;
            }
            for (suffix, tag) in &lex.suffix_rules {
                if tok.chars().count() > suffix.chars().count() + 1 && tok.ends_with(suffix) {
                    return *tag;
                }
            }
            Tag::Noun
        })
        .collect()
}
