//! Vocabulary: id <-> word mapping loaded from the lexicon export.
//!
//! The id numbering is fixed by `textproc.build_vocab` on the python side
//! (specials 0..3, then sorted known words, then filler); rust only loads
//! it — it never rebuilds the list — so both sides are identical by
//! construction.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::lexicon::Lexicon;
use super::tokenizer::tokenize;

/// Padding token id (fixed by the python build).
pub const PAD_ID: i32 = 0;
/// Beginning-of-sequence token id.
pub const BOS_ID: i32 = 1;
/// End-of-sequence token id.
pub const EOS_ID: i32 = 2;
/// Unknown-word token id.
pub const UNK_ID: i32 = 3;

/// The id <-> word mapping.
#[derive(Debug)]
pub struct Vocab {
    /// Words in id order (specials included).
    pub id_to_word: Vec<String>,
    word_to_id: HashMap<String, i32>,
}

impl Vocab {
    /// Adopt the lexicon's word list (size checked against the
    /// manifest).
    pub fn from_lexicon(lex: &Lexicon, expected_size: usize) -> Result<Vocab> {
        ensure!(
            lex.vocab_words.len() == expected_size,
            "vocab size mismatch: lexicon has {}, manifest says {}",
            lex.vocab_words.len(),
            expected_size
        );
        let word_to_id = lex
            .vocab_words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Ok(Vocab { id_to_word: lex.vocab_words.clone(), word_to_id })
    }

    /// Vocabulary size.
    pub fn len(&self) -> usize {
        self.id_to_word.len()
    }

    /// Is the vocabulary empty?
    pub fn is_empty(&self) -> bool {
        self.id_to_word.is_empty()
    }

    /// Tokenize and map to ids (unknown words -> [`UNK_ID`]),
    /// optionally truncated.
    pub fn encode(&self, text: &str, max_len: Option<usize>) -> Vec<i32> {
        let mut ids: Vec<i32> = tokenize(text)
            .iter()
            .map(|t| self.word_to_id.get(t).copied().unwrap_or(UNK_ID))
            .collect();
        if let Some(n) = max_len {
            ids.truncate(n);
        }
        ids
    }

    /// Map ids back to a space-joined string (specials skipped).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut words = Vec::new();
        for &id in ids {
            if id == PAD_ID || id == BOS_ID || id == EOS_ID {
                continue;
            }
            match self.id_to_word.get(id as usize) {
                Some(w) => words.push(w.as_str()),
                None => words.push("<unk>"),
            }
        }
        words.join(" ")
    }
}
