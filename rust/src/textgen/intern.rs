//! Interned-lexicon scoring table: the compiled form of [`Lexicon`]
//! that the single-pass RULEGEN fast path scores against.
//!
//! The legacy scorers in [`crate::uncertainty::rules`] re-hash every
//! token against ~10 separate `String`-keyed sets (SipHash each time)
//! and re-scan the suffix rules with an O(len) `chars().count()` per
//! rule. This module folds all of that into **one** table built once at
//! [`Lexicon`] load:
//!
//! - every word of every rule list is interned into a single arena and
//!   indexed by an open-addressed FNV-1a table, so the hot loop does one
//!   non-cryptographic hash + one probe per token;
//! - each interned word carries a [`WordInfo`]: its PoS tag (when the
//!   PoS lexicon defines one), a class-flag bitset covering every rule
//!   list membership, and the homonym sense count;
//! - multi-word phrases (`vague_phrases` and `open_score`'s hardcoded
//!   "do you think") are compiled to interned word-id sequences, so
//!   phrase containment is integer-slice comparison instead of
//!   per-window `String` equality;
//! - suffix rules are precompiled with their byte form and char count,
//!   so the fallback tagger compares byte suffixes and counts the
//!   token's chars at most once.
//!
//! The table is a pure acceleration structure: it holds exactly the
//! same facts as the `Lexicon`'s sets/maps, and the fast path that
//! reads it ([`crate::uncertainty::fastpath`]) is asserted bit-identical
//! to the legacy scorers by the golden and property suites.

use std::collections::BTreeMap;

use super::lexicon::{Lexicon, Tag};

/// Word id of a token that is not in the table (matches no phrase and
/// carries no flags). Real ids are indices into the entry list, which
/// is always far smaller than `u32::MAX`.
pub const NO_WORD: u32 = u32::MAX;

/// Set when the PoS lexicon defines a tag for this word (`tag` field is
/// meaningful; otherwise tagging falls through to the suffix rules).
pub const FLAG_POS: u16 = 1 << 0;
/// Member of `nv_ambiguous` (syntactic-ambiguity rule).
pub const FLAG_NV_AMBIG: u16 = 1 << 1;
/// Key of `homonyms` (`senses` field holds the sense count).
pub const FLAG_HOMONYM: u16 = 1 << 2;
/// Member of `vague_topics`.
pub const FLAG_VAGUE_TOPIC: u16 = 1 << 3;
/// Member of `vague_adjectives`.
pub const FLAG_VAGUE_ADJ: u16 = 1 << 4;
/// Member of `open_markers`.
pub const FLAG_OPEN_MARKER: u16 = 1 << 5;
/// Member of `multipart_markers`.
pub const FLAG_MULTIPART: u16 = 1 << 6;
/// Member of `relativizers`.
pub const FLAG_RELATIVIZER: u16 = 1 << 7;
/// Member of `wh_words`.
pub const FLAG_WH: u16 = 1 << 8;
/// Member of `open_wh_starters`.
pub const FLAG_OPEN_WH: u16 = 1 << 9;
/// Appears in some compiled phrase (vague phrase or "do you think").
pub const FLAG_PHRASE: u16 = 1 << 10;
/// The literal word "of" (`open_score`'s `what ... of` pattern).
pub const FLAG_OF: u16 = 1 << 11;
/// The literal word "and" (`multipart_score`'s conjunction count).
pub const FLAG_AND: u16 = 1 << 12;

/// Everything the single-pass scorer needs to know about one interned
/// word: class-membership flags, the PoS tag (valid when [`FLAG_POS`]
/// is set), and the homonym sense count (valid when [`FLAG_HOMONYM`]
/// is set).
#[derive(Clone, Copy, Debug)]
pub struct WordInfo {
    /// Class-membership bitset (`FLAG_*`).
    pub flags: u16,
    /// PoS-lexicon tag; meaningful only when `flags` has [`FLAG_POS`].
    pub tag: Tag,
    /// Homonym sense count; meaningful only when `flags` has
    /// [`FLAG_HOMONYM`]. Kept `u32` so the fast path computes the same
    /// `senses - 1` arithmetic as the legacy scorer.
    pub senses: u32,
}

/// One suffix rule, precompiled: byte form for `ends_with`, char count
/// for the legacy `chars().count() > suffix_chars + 1` length guard.
#[derive(Debug)]
struct CompiledSuffix {
    bytes: Box<[u8]>,
    chars: usize,
    tag: Tag,
}

#[derive(Debug)]
struct Entry {
    /// Byte span of the word in the arena.
    start: u32,
    end: u32,
    info: WordInfo,
}

/// 64-bit FNV-1a over a byte slice — the table's non-cryptographic
/// hasher (`anyhow` stays the crate's sole dependency).
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The compiled scoring table: one unified `word -> WordInfo` map plus
/// interned phrase-id sequences and precompiled suffix rules. Built
/// once by [`Lexicon::from_json`]; read-only afterwards (shared freely
/// across threads behind the `Arc<Lexicon>`).
#[derive(Debug, Default)]
pub struct ScoreTable {
    /// All interned words, concatenated.
    arena: String,
    /// Interned words in id order.
    entries: Vec<Entry>,
    /// Open-addressed index: `0` = empty, else entry id + 1. Length is
    /// a power of two with load factor <= 0.5, so probes terminate.
    slots: Vec<u32>,
    /// `vague_phrases` as interned word-id sequences, in lexicon order.
    vague_phrases: Vec<Box<[u32]>>,
    /// `open_score`'s hardcoded "do you think" as interned word ids.
    think: Box<[u32]>,
    /// Suffix rules in lexicon order.
    suffixes: Vec<CompiledSuffix>,
}

impl ScoreTable {
    /// Compile a lexicon's word lists into the unified table. Pure: the
    /// table holds the same facts the lexicon's sets/maps do.
    pub fn compile(lex: &Lexicon) -> ScoreTable {
        // Deterministic build: merge every list into one sorted
        // word -> WordInfo map (iteration over the HashSets would
        // scramble ids run to run for no benefit).
        let mut words: BTreeMap<&str, WordInfo> = BTreeMap::new();
        let merge = |words: &mut BTreeMap<&str, WordInfo>, word, flags: u16| {
            let info = words
                .entry(word)
                .or_insert(WordInfo { flags: 0, tag: Tag::Other, senses: 0 });
            info.flags |= flags;
        };
        for (word, tag) in &lex.pos_lexicon {
            let info = words
                .entry(word)
                .or_insert(WordInfo { flags: 0, tag: Tag::Other, senses: 0 });
            info.flags |= FLAG_POS;
            info.tag = *tag;
        }
        for word in &lex.nv_ambiguous {
            merge(&mut words, word.as_str(), FLAG_NV_AMBIG);
        }
        for (word, senses) in &lex.homonyms {
            let info = words
                .entry(word)
                .or_insert(WordInfo { flags: 0, tag: Tag::Other, senses: 0 });
            info.flags |= FLAG_HOMONYM;
            info.senses = *senses;
        }
        for word in &lex.vague_topics {
            merge(&mut words, word.as_str(), FLAG_VAGUE_TOPIC);
        }
        for word in &lex.vague_adjectives {
            merge(&mut words, word.as_str(), FLAG_VAGUE_ADJ);
        }
        for word in &lex.open_markers {
            merge(&mut words, word.as_str(), FLAG_OPEN_MARKER);
        }
        for word in &lex.multipart_markers {
            merge(&mut words, word.as_str(), FLAG_MULTIPART);
        }
        for word in &lex.relativizers {
            merge(&mut words, word.as_str(), FLAG_RELATIVIZER);
        }
        for word in &lex.wh_words {
            merge(&mut words, word.as_str(), FLAG_WH);
        }
        for word in &lex.open_wh_starters {
            merge(&mut words, word.as_str(), FLAG_OPEN_WH);
        }
        merge(&mut words, "of", FLAG_OF);
        merge(&mut words, "and", FLAG_AND);
        for phrase in &lex.vague_phrases {
            for word in phrase {
                merge(&mut words, word.as_str(), FLAG_PHRASE);
            }
        }
        for word in THINK_PHRASE {
            merge(&mut words, word, FLAG_PHRASE);
        }

        // Freeze: arena + entries in sorted-word order, then the
        // open-addressed index at load factor <= 0.5.
        let mut arena = String::new();
        let mut entries = Vec::with_capacity(words.len());
        for (word, info) in &words {
            let start = arena.len() as u32;
            arena.push_str(word);
            entries.push(Entry { start, end: arena.len() as u32, info: *info });
        }
        let cap = (entries.len() * 2).next_power_of_two().max(4);
        let mut slots = vec![0u32; cap];
        for (id, entry) in entries.iter().enumerate() {
            let word = &arena.as_bytes()[entry.start as usize..entry.end as usize];
            let mut idx = fnv1a(word) as usize & (cap - 1);
            while slots[idx] != 0 {
                idx = (idx + 1) & (cap - 1);
            }
            slots[idx] = id as u32 + 1;
        }

        let mut table = ScoreTable {
            arena,
            entries,
            slots,
            vague_phrases: Vec::new(),
            think: Box::new([]),
            suffixes: lex
                .suffix_rules
                .iter()
                .map(|(suffix, tag)| CompiledSuffix {
                    bytes: suffix.as_bytes().into(),
                    chars: suffix.chars().count(),
                    tag: *tag,
                })
                .collect(),
        };
        table.vague_phrases = lex
            .vague_phrases
            .iter()
            .map(|phrase| {
                phrase
                    .iter()
                    .map(|w| table.lookup(w.as_bytes()).map(|(id, _)| id).unwrap_or(NO_WORD))
                    .collect()
            })
            .collect();
        table.think = THINK_PHRASE
            .iter()
            .map(|w| table.lookup(w.as_bytes()).map(|(id, _)| id).unwrap_or(NO_WORD))
            .collect();
        table
    }

    /// One-probe lookup of a (lowercased) token: its interned word id
    /// and [`WordInfo`], or `None` when the word is in no rule list.
    #[inline]
    pub fn lookup(&self, word: &[u8]) -> Option<(u32, WordInfo)> {
        let mask = self.slots.len() - 1;
        let mut idx = fnv1a(word) as usize & mask;
        loop {
            let slot = self.slots[idx];
            if slot == 0 {
                return None;
            }
            let entry = &self.entries[(slot - 1) as usize];
            if &self.arena.as_bytes()[entry.start as usize..entry.end as usize] == word {
                return Some((slot - 1, entry.info));
            }
            idx = (idx + 1) & mask;
        }
    }

    /// Fallback tag of a token the PoS lexicon does not cover: first
    /// suffix rule whose byte suffix matches and whose length guard
    /// holds (the legacy `chars().count() > suffix_chars + 1`), else
    /// `NOUN`. The token's char count is computed at most once.
    #[inline]
    pub fn suffix_tag(&self, token: &[u8]) -> Tag {
        let mut chars = usize::MAX; // computed lazily on first byte match
        for rule in &self.suffixes {
            if token.ends_with(&rule.bytes) {
                if chars == usize::MAX {
                    chars = token.iter().filter(|&&b| (b & 0xC0) != 0x80).count();
                }
                if chars > rule.chars + 1 {
                    return rule.tag;
                }
            }
        }
        Tag::Noun
    }

    /// The compiled `vague_phrases`, as interned word-id sequences in
    /// lexicon order.
    #[inline]
    pub fn vague_phrases(&self) -> &[Box<[u32]>] {
        &self.vague_phrases
    }

    /// The compiled "do you think" phrase (interned word ids).
    #[inline]
    pub fn think_phrase(&self) -> &[u32] {
        &self.think
    }

    /// Number of interned words (diagnostics / tests).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no word list contributed any word.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The open-endedness scorer's hardcoded phrase (see
/// [`crate::uncertainty::rules::open_score`]).
pub const THINK_PHRASE: &[&str] = &["do", "you", "think"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn rich_lexicon() -> Lexicon {
        let json = r#"{
            "vocab": ["<pad>", "<bos>", "<eos>", "<unk>"],
            "pos_lexicon": {"in": "ADP", "runs": "VERB", "happily": "ADV", "and": "CONJ"},
            "suffix_rules": [["ly", "ADV"], ["ing", "VERB"], ["tion", "NOUN"]],
            "homonyms": {"bank": 3, "scale": 4},
            "nv_ambiguous": ["saw", "duck"],
            "vague_topics": ["history"],
            "vague_phrases": [["tell", "me", "about"], ["describe"]],
            "open_markers": ["causes"],
            "multipart_markers": ["both"],
            "relativizers": ["that"],
            "wh_words": ["what", "who"],
            "vague_adjectives": ["general"],
            "open_wh_starters": ["what"]
        }"#;
        Lexicon::from_json(&Json::parse(json).unwrap()).unwrap()
    }

    #[test]
    fn lookup_merges_flags_across_lists() {
        let lex = rich_lexicon();
        let t = &lex.compiled;
        let (_, what) = t.lookup(b"what").expect("'what' interned");
        assert_ne!(what.flags & FLAG_WH, 0);
        assert_ne!(what.flags & FLAG_OPEN_WH, 0);
        assert_eq!(what.flags & FLAG_POS, 0);
        let (_, and) = t.lookup(b"and").expect("'and' interned");
        assert_ne!(and.flags & FLAG_AND, 0);
        assert_ne!(and.flags & FLAG_POS, 0);
        assert_eq!(and.tag, Tag::Conj);
        let (_, bank) = t.lookup(b"bank").expect("'bank' interned");
        assert_ne!(bank.flags & FLAG_HOMONYM, 0);
        assert_eq!(bank.senses, 3);
        assert!(t.lookup(b"unlisted").is_none());
        assert!(t.lookup(b"").is_none());
    }

    #[test]
    fn phrases_intern_to_valid_ids() {
        let lex = rich_lexicon();
        let t = &lex.compiled;
        assert_eq!(t.vague_phrases().len(), 2);
        for phrase in t.vague_phrases() {
            for &id in phrase.iter() {
                assert!(id != NO_WORD && (id as usize) < t.len());
            }
        }
        assert_eq!(t.think_phrase().len(), 3);
        let (do_id, _) = t.lookup(b"do").expect("'do' interned for the think phrase");
        assert_eq!(t.think_phrase()[0], do_id);
    }

    #[test]
    fn suffix_tag_matches_legacy_rules() {
        let lex = rich_lexicon();
        let t = &lex.compiled;
        // "quickly": 7 chars > 2 + 1, ends with "ly" -> ADV
        assert_eq!(t.suffix_tag(b"quickly"), Tag::Adv);
        // "fly": 3 chars, not > 2 + 1 -> falls through to NOUN
        assert_eq!(t.suffix_tag(b"fly"), Tag::Noun);
        assert_eq!(t.suffix_tag(b"running"), Tag::Verb);
        assert_eq!(t.suffix_tag(b"station"), Tag::Noun);
        assert_eq!(t.suffix_tag(b"zebra"), Tag::Noun);
        // multi-byte chars count as one char, as chars().count() does
        assert_eq!(t.suffix_tag("caf\u{e9}ly".as_bytes()), Tag::Adv);
    }

    #[test]
    fn empty_lexicon_compiles_and_misses() {
        let json = r#"{
            "vocab": [], "pos_lexicon": {}, "suffix_rules": [],
            "homonyms": {}, "nv_ambiguous": [], "vague_topics": [],
            "vague_phrases": [], "open_markers": [], "multipart_markers": [],
            "relativizers": [], "wh_words": [], "vague_adjectives": [],
            "open_wh_starters": []
        }"#;
        let lex = Lexicon::from_json(&Json::parse(json).unwrap()).unwrap();
        // "of", "and", and the think-phrase words are always interned
        assert!(!lex.compiled.is_empty());
        assert!(lex.compiled.lookup(b"anything").is_none());
        assert_eq!(lex.compiled.suffix_tag(b"quickly"), Tag::Noun);
    }
}
