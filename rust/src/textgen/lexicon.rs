//! Lexicon loaded from `artifacts/lexicon.json` (exported by aot.py from
//! `python/compile/lexicon.py`, the single source of truth).

use std::collections::{HashMap, HashSet};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::intern::ScoreTable;
use crate::util::json::Json;

/// PoS-lite tag inventory (mirror of python's TAG_* constants).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tag {
    /// Noun.
    Noun,
    /// Verb.
    Verb,
    /// Adjective.
    Adj,
    /// Adverb.
    Adv,
    /// Pronoun.
    Pron,
    /// Determiner.
    Det,
    /// Adposition.
    Adp,
    /// Conjunction.
    Conj,
    /// Wh-word (who/what/which...).
    Wh,
    /// Punctuation token.
    Punct,
    /// Anything else.
    Other,
}

impl Tag {
    /// Parse python's TAG_* string form.
    pub fn from_str(s: &str) -> Result<Tag> {
        Ok(match s {
            "NOUN" => Tag::Noun,
            "VERB" => Tag::Verb,
            "ADJ" => Tag::Adj,
            "ADV" => Tag::Adv,
            "PRON" => Tag::Pron,
            "DET" => Tag::Det,
            "ADP" => Tag::Adp,
            "CONJ" => Tag::Conj,
            "WH" => Tag::Wh,
            "PUNCT" => Tag::Punct,
            "OTHER" => Tag::Other,
            other => return Err(anyhow!("unknown tag '{other}'")),
        })
    }

    /// The python TAG_* string form.
    pub fn as_str(&self) -> &'static str {
        match self {
            Tag::Noun => "NOUN",
            Tag::Verb => "VERB",
            Tag::Adj => "ADJ",
            Tag::Adv => "ADV",
            Tag::Pron => "PRON",
            Tag::Det => "DET",
            Tag::Adp => "ADP",
            Tag::Conj => "CONJ",
            Tag::Wh => "WH",
            Tag::Punct => "PUNCT",
            Tag::Other => "OTHER",
        }
    }
}

/// All word lists RULEGEN and the tagger need, parsed once at startup.
#[derive(Debug)]
pub struct Lexicon {
    /// Vocabulary words, in id order.
    pub vocab_words: Vec<String>,
    /// word -> tag dictionary of the PoS-lite tagger.
    pub pos_lexicon: HashMap<String, Tag>,
    /// (suffix, tag) fallback rules, tried in order.
    pub suffix_rules: Vec<(String, Tag)>,
    /// Noun/verb-ambiguous words (syntactic-ambiguity rule).
    pub nv_ambiguous: HashSet<String>,
    /// word -> sense count (semantic-ambiguity rule).
    pub homonyms: HashMap<String, u32>,
    /// Topics the vagueness rule treats as broad.
    pub vague_topics: HashSet<String>,
    /// Multi-word vague phrases.
    pub vague_phrases: Vec<Vec<String>>,
    /// Open-endedness markers.
    pub open_markers: HashSet<String>,
    /// Multi-part-question markers.
    pub multipart_markers: HashSet<String>,
    /// Relativizer words (clause-complexity rule).
    pub relativizers: HashSet<String>,
    /// Wh-question words.
    pub wh_words: HashSet<String>,
    /// Adjectives the vagueness rule counts.
    pub vague_adjectives: HashSet<String>,
    /// Wh-starters marking open-ended questions.
    pub open_wh_starters: HashSet<String>,
    /// The interned scoring table compiled from the lists above — the
    /// single-lookup structure the RULEGEN fast path reads. Built once
    /// at load; holds exactly the same facts as the sets/maps, so it
    /// never needs separate updating.
    pub compiled: ScoreTable,
}

fn str_list(v: &Json, key: &str) -> Result<Vec<String>> {
    v.need_arr(key)?
        .iter()
        .map(|j| {
            j.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("'{key}' contains a non-string"))
        })
        .collect()
}

fn str_set(v: &Json, key: &str) -> Result<HashSet<String>> {
    Ok(str_list(v, key)?.into_iter().collect())
}

impl Lexicon {
    /// Load `lexicon.json` from disk.
    pub fn load(path: &Path) -> Result<Lexicon> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading lexicon {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing lexicon: {e}"))?;
        Self::from_json(&v)
    }

    /// Parse an in-memory lexicon JSON value.
    pub fn from_json(v: &Json) -> Result<Lexicon> {
        let mut pos_lexicon = HashMap::new();
        for (word, tag) in v.need_obj("pos_lexicon")? {
            pos_lexicon.insert(
                word.clone(),
                Tag::from_str(tag.as_str().ok_or_else(|| anyhow!("bad tag value"))?)?,
            );
        }
        let mut suffix_rules = Vec::new();
        for rule in v.need_arr("suffix_rules")? {
            let suffix = rule.idx(0).as_str().ok_or_else(|| anyhow!("bad suffix"))?;
            let tag = Tag::from_str(rule.idx(1).as_str().ok_or_else(|| anyhow!("bad tag"))?)?;
            suffix_rules.push((suffix.to_string(), tag));
        }
        let mut homonyms = HashMap::new();
        for (word, senses) in v.need_obj("homonyms")? {
            homonyms.insert(
                word.clone(),
                senses.as_f64().ok_or_else(|| anyhow!("bad sense count"))? as u32,
            );
        }
        let vague_phrases = v
            .need_arr("vague_phrases")?
            .iter()
            .map(|p| {
                p.as_arr()
                    .ok_or_else(|| anyhow!("bad phrase"))?
                    .iter()
                    .map(|w| {
                        w.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow!("bad phrase word"))
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;

        let mut lex = Lexicon {
            vocab_words: str_list(v, "vocab")?,
            pos_lexicon,
            suffix_rules,
            nv_ambiguous: str_set(v, "nv_ambiguous")?,
            homonyms,
            vague_topics: str_set(v, "vague_topics")?,
            vague_phrases,
            open_markers: str_set(v, "open_markers")?,
            multipart_markers: str_set(v, "multipart_markers")?,
            relativizers: str_set(v, "relativizers")?,
            wh_words: str_set(v, "wh_words")?,
            vague_adjectives: str_set(v, "vague_adjectives")?,
            open_wh_starters: str_set(v, "open_wh_starters")?,
            compiled: ScoreTable::default(),
        };
        lex.compiled = ScoreTable::compile(&lex);
        Ok(lex)
    }
}
